//! Spec-keyed micro-batch queues: the coalescing heart of `decorr serve`.
//!
//! Requests land in per-`(spec, d)` queues. Score requests carry
//! independent rows, so they coalesce: a worker takes whole requests
//! until the batch reaches the configured capacity (the artifact's batch
//! shape), or the oldest waiting request ages past the flush deadline,
//! or a graceful drain flushes the remainder. Diagnose requests are
//! whole-matrix jobs — they never merge, but ride the same queues so a
//! warm per-spec executor serves both kinds.
//!
//! Everything here is pure data structure plus clock arithmetic — the
//! `now` instant is a parameter, so flush policy is unit-tested without
//! sockets or sleeps. The server wraps one [`QueueSet`] in a
//! `Mutex`/`Condvar` pair; [`QueueSet::next_deadline`] bounds the
//! condvar wait so deadline flushes fire on time.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::metrics::FlushReason;
use super::protocol::RequestKind;

/// Queue identity: requests only coalesce when both the spec label and
/// the embedding dimension agree (one executor/plan per key).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct QueueKey {
    /// Canonical spec label.
    pub spec: String,
    /// Embedding dimension.
    pub d: usize,
}

/// One queued request, with the reply handle the server will scatter the
/// result back through. Generic over the handle so the queue logic tests
/// without connections.
#[derive(Debug)]
pub struct Job<R> {
    /// Client request id.
    pub id: u64,
    /// Score (coalescable rows) or Diagnose (whole matrix).
    pub kind: RequestKind,
    /// Row count of each view.
    pub rows: usize,
    /// View A, row-major `rows · d`.
    pub a: Vec<f32>,
    /// View B, row-major `rows · d`.
    pub b: Vec<f32>,
    /// When the request finished decoding (latency measurement origin).
    pub arrival: Instant,
    /// Where the response goes.
    pub reply: R,
}

#[derive(Debug)]
struct SpecQueue<R> {
    score: VecDeque<Job<R>>,
    score_rows: usize,
    diag: VecDeque<Job<R>>,
}

impl<R> Default for SpecQueue<R> {
    fn default() -> Self {
        SpecQueue {
            score: VecDeque::new(),
            score_rows: 0,
            diag: VecDeque::new(),
        }
    }
}

/// A batch a worker claimed from the queues.
#[derive(Debug)]
pub enum Taken<R> {
    /// One whole-matrix diagnose job.
    Diagnose {
        /// Queue it came from.
        key: QueueKey,
        /// The job.
        job: Job<R>,
    },
    /// A coalesced score micro-batch: whole requests, in arrival order,
    /// whose rows sum to at most the capacity.
    Score {
        /// Queue it came from.
        key: QueueKey,
        /// The member requests, arrival order.
        jobs: Vec<Job<R>>,
        /// Total real rows across `jobs`.
        rows: usize,
        /// Why the batch flushed.
        reason: FlushReason,
        /// Rows still waiting in this queue after the take (the
        /// queue-depth gauge sample).
        depth_after: usize,
    },
}

/// The spec-keyed queue set. See the module docs.
#[derive(Debug)]
pub struct QueueSet<R> {
    queues: BTreeMap<QueueKey, SpecQueue<R>>,
}

impl<R> Default for QueueSet<R> {
    fn default() -> Self {
        QueueSet {
            queues: BTreeMap::new(),
        }
    }
}

impl<R> QueueSet<R> {
    /// Enqueue a decoded request.
    pub fn push(&mut self, key: QueueKey, job: Job<R>) {
        let q = self.queues.entry(key).or_default();
        match job.kind {
            RequestKind::Score => {
                q.score_rows += job.rows;
                q.score.push_back(job);
            }
            RequestKind::Diagnose => q.diag.push_back(job),
        }
    }

    /// Whether nothing is waiting anywhere.
    pub fn is_empty(&self) -> bool {
        self.queues
            .values()
            .all(|q| q.score.is_empty() && q.diag.is_empty())
    }

    /// Rows currently waiting in the score queue for `key`.
    pub fn depth_rows(&self, key: &QueueKey) -> usize {
        self.queues.get(key).map_or(0, |q| q.score_rows)
    }

    /// Claim the next ready batch, if any:
    ///
    /// 1. any waiting diagnose job (whole-matrix, never coalesced);
    /// 2. a score queue holding `capacity`+ rows — a *full* flush;
    /// 3. a score queue whose oldest job aged past `deadline` — a
    ///    *deadline* flush;
    /// 4. under `drain`, any non-empty score queue — a *drain* flush.
    ///
    /// Requests are atomic: a batch takes whole jobs in arrival order
    /// while they fit, so one batch never splits a request's rows.
    pub fn take_ready(
        &mut self,
        now: Instant,
        capacity: usize,
        deadline: Duration,
        drain: bool,
    ) -> Option<Taken<R>> {
        // 1: diagnose jobs.
        let diag_key = self
            .queues
            .iter()
            .find(|(_, q)| !q.diag.is_empty())
            .map(|(k, _)| k.clone());
        if let Some(key) = diag_key {
            let job = self
                .queues
                .get_mut(&key)
                .and_then(|q| q.diag.pop_front())
                .expect("diag job present under lock");
            return Some(Taken::Diagnose { key, job });
        }
        // 2–4: score batches, by decreasing urgency.
        let pick = |q: &SpecQueue<R>| -> Option<FlushReason> {
            if q.score.is_empty() {
                return None;
            }
            if q.score_rows >= capacity {
                return Some(FlushReason::Full);
            }
            let oldest = q.score.front().expect("non-empty").arrival;
            if now.duration_since(oldest) >= deadline {
                return Some(FlushReason::Deadline);
            }
            if drain {
                return Some(FlushReason::Drain);
            }
            None
        };
        let mut chosen: Option<(QueueKey, FlushReason)> = None;
        for (k, q) in &self.queues {
            if let Some(reason) = pick(q) {
                // Full beats deadline beats drain; first key wins ties.
                let better = match (&chosen, reason) {
                    (None, _) => true,
                    (Some((_, FlushReason::Full)), _) => false,
                    (Some(_), FlushReason::Full) => true,
                    (Some((_, FlushReason::Deadline)), _) => false,
                    (Some(_), FlushReason::Deadline) => true,
                    _ => false,
                };
                if better {
                    chosen = Some((k.clone(), reason));
                }
            }
        }
        let (key, reason) = chosen?;
        let q = self.queues.get_mut(&key).expect("chosen key exists");
        let mut jobs = Vec::new();
        let mut rows = 0usize;
        while let Some(front) = q.score.front() {
            if !jobs.is_empty() && rows + front.rows > capacity {
                break;
            }
            let job = q.score.pop_front().expect("front present");
            q.score_rows -= job.rows;
            rows += job.rows;
            jobs.push(job);
            if rows >= capacity {
                break;
            }
        }
        let depth_after = q.score_rows;
        Some(Taken::Score {
            key,
            jobs,
            rows,
            reason,
            depth_after,
        })
    }

    /// Time until the earliest pending flush deadline (zero if one has
    /// already passed), or `None` when no score rows are waiting. Bounds
    /// the worker condvar wait.
    pub fn next_deadline(&self, now: Instant, deadline: Duration) -> Option<Duration> {
        self.queues
            .values()
            .filter_map(|q| q.score.front())
            .map(|j| {
                let age = now.duration_since(j.arrival);
                deadline.saturating_sub(age)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, kind: RequestKind, rows: usize, arrival: Instant) -> Job<u64> {
        Job {
            id,
            kind,
            rows,
            a: vec![0.0; rows * 4],
            b: vec![0.0; rows * 4],
            arrival,
            reply: id,
        }
    }

    fn key(spec: &str) -> QueueKey {
        QueueKey {
            spec: spec.to_string(),
            d: 4,
        }
    }

    const DEADLINE: Duration = Duration::from_millis(5);

    #[test]
    fn full_batch_flushes_immediately() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        for i in 0..4 {
            qs.push(key("bt_sum"), job(i, RequestKind::Score, 4, t0));
        }
        // 16 rows at capacity 8: a full batch is ready right now.
        match qs.take_ready(t0, 8, DEADLINE, false) {
            Some(Taken::Score {
                jobs,
                rows,
                reason,
                depth_after,
                ..
            }) => {
                assert_eq!(jobs.len(), 2);
                assert_eq!(rows, 8);
                assert_eq!(reason, FlushReason::Full);
                assert_eq!(depth_after, 8);
                assert_eq!(jobs[0].id, 0);
                assert_eq!(jobs[1].id, 1);
            }
            other => panic!("expected full score batch, got {other:?}"),
        }
        // Remaining 8 rows flush as the second full batch.
        match qs.take_ready(t0, 8, DEADLINE, false) {
            Some(Taken::Score { rows, reason, .. }) => {
                assert_eq!(rows, 8);
                assert_eq!(reason, FlushReason::Full);
            }
            other => panic!("{other:?}"),
        }
        assert!(qs.is_empty());
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        qs.push(key("bt_sum"), job(1, RequestKind::Score, 3, t0));
        // Young and under capacity: not ready.
        assert!(qs.take_ready(t0, 8, DEADLINE, false).is_none());
        let wait = qs.next_deadline(t0, DEADLINE).unwrap();
        assert!(wait <= DEADLINE);
        // Past the deadline: flushes partial.
        match qs.take_ready(t0 + DEADLINE, 8, DEADLINE, false) {
            Some(Taken::Score {
                rows,
                reason,
                depth_after,
                ..
            }) => {
                assert_eq!(rows, 3);
                assert_eq!(reason, FlushReason::Deadline);
                assert_eq!(depth_after, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn requests_are_atomic_across_batches() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        qs.push(key("s"), job(1, RequestKind::Score, 5, t0));
        qs.push(key("s"), job(2, RequestKind::Score, 5, t0));
        // Capacity 8 fits one 5-row request but not two: the second
        // request is never split.
        match qs.take_ready(t0 + DEADLINE, 8, DEADLINE, false) {
            Some(Taken::Score { jobs, rows, .. }) => {
                assert_eq!(jobs.len(), 1);
                assert_eq!(rows, 5);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(qs.depth_rows(&key("s")), 5);
    }

    #[test]
    fn specs_never_coalesce_together() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        qs.push(key("a"), job(1, RequestKind::Score, 4, t0));
        qs.push(key("b"), job(2, RequestKind::Score, 4, t0));
        let taken = qs.take_ready(t0 + DEADLINE, 8, DEADLINE, false).unwrap();
        match taken {
            Taken::Score { key: k, jobs, .. } => {
                assert_eq!(jobs.len(), 1, "one spec per batch");
                assert_eq!(k.spec, "a");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn diagnose_preempts_and_never_merges() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        qs.push(key("s"), job(1, RequestKind::Score, 8, t0));
        qs.push(key("s"), job(2, RequestKind::Diagnose, 32, t0));
        match qs.take_ready(t0, 8, DEADLINE, false) {
            Some(Taken::Diagnose { job, .. }) => assert_eq!(job.id, 2),
            other => panic!("{other:?}"),
        }
        match qs.take_ready(t0, 8, DEADLINE, false) {
            Some(Taken::Score { jobs, .. }) => assert_eq!(jobs[0].id, 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn drain_flushes_everything() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        qs.push(key("a"), job(1, RequestKind::Score, 2, t0));
        qs.push(key("b"), job(2, RequestKind::Score, 1, t0));
        let mut seen = 0;
        while let Some(t) = qs.take_ready(t0, 8, DEADLINE, true) {
            match t {
                Taken::Score { reason, jobs, .. } => {
                    assert_eq!(reason, FlushReason::Drain);
                    seen += jobs.len();
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(seen, 2);
        assert!(qs.is_empty());
    }

    #[test]
    fn full_beats_deadline_beats_drain() {
        let mut qs = QueueSet::default();
        let t0 = Instant::now();
        qs.push(key("young_full"), job(1, RequestKind::Score, 8, t0 + DEADLINE));
        qs.push(key("old_partial"), job(2, RequestKind::Score, 2, t0));
        match qs.take_ready(t0 + DEADLINE, 8, DEADLINE, true) {
            Some(Taken::Score { key: k, reason, .. }) => {
                assert_eq!(k.spec, "young_full");
                assert_eq!(reason, FlushReason::Full);
            }
            other => panic!("{other:?}"),
        }
        match qs.take_ready(t0 + DEADLINE, 8, DEADLINE, true) {
            Some(Taken::Score { key: k, reason, .. }) => {
                assert_eq!(k.spec, "old_partial");
                assert_eq!(reason, FlushReason::Deadline);
            }
            other => panic!("{other:?}"),
        }
    }
}
