//! Typed configuration + a TOML-subset parser.
//!
//! The coordinator is configured by (in increasing precedence): built-in
//! preset defaults → a config file (TOML subset: `key = value` pairs and
//! `[section]` headers; strings, numbers, booleans) → CLI `--key value`
//! overrides. The parser is ours (offline environment, no serde/toml).
//!
//! The loss itself is configured as a typed [`LossSpec`] (the `api` front
//! door): the `variant` key accepts both the legacy artifact fragments
//! (`"bt_sum"`, `"vic_sum_g128"`) and the full spec grammar
//! (`"vic_sum@b=64,q=1"`), case-insensitively. The closed [`Variant`]
//! enum survives as a thin alias layer naming the paper's six table
//! presets.

mod toml;

pub use toml::{parse_toml, TomlDoc, TomlValue};

use anyhow::{bail, Result};

use crate::api::LossSpec;
use crate::util::cli::Args;

/// The paper's six table presets (matching the artifact names emitted by
/// `aot.py`).
///
/// **Legacy alias layer.** `Variant` predates the typed [`LossSpec`] API
/// and names only the closed set the paper tabulates; every member
/// converts losslessly via [`Variant::spec`] (see `api::compat`), and the
/// spec space is a strict superset (any block size, either `q`, norm
/// convention, λ, threads). Prefer `LossSpec` in new code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Original Barlow Twins (R_off on C(A,B)).
    BtOff,
    /// Proposed BT-style FFT regularizer (R_sum).
    BtSum,
    /// Proposed BT-style with feature grouping b=128.
    BtSumG128,
    /// Original VICReg (R_off on K(A), K(B)).
    VicOff,
    /// Proposed VICReg-style FFT regularizer.
    VicSum,
    /// Proposed VICReg-style with feature grouping b=128.
    VicSumG128,
}

impl Variant {
    /// Artifact-name fragment ("bt_sum", ...).
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::BtOff => "bt_off",
            Variant::BtSum => "bt_sum",
            Variant::BtSumG128 => "bt_sum_g128",
            Variant::VicOff => "vic_off",
            Variant::VicSum => "vic_sum",
            Variant::VicSumG128 => "vic_sum_g128",
        }
    }

    /// Parse from the artifact-name fragment (case-insensitive).
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "bt_off" => Variant::BtOff,
            "bt_sum" => Variant::BtSum,
            "bt_sum_g128" => Variant::BtSumG128,
            "vic_off" => Variant::VicOff,
            "vic_sum" => Variant::VicSum,
            "vic_sum_g128" => Variant::VicSumG128,
            other => bail!(
                "unknown variant '{other}' (valid: bt_off, bt_sum, bt_sum_g128, \
                 vic_off, vic_sum, vic_sum_g128; or a loss spec like 'bt_sum@b=64,q=1')"
            ),
        })
    }

    /// All variants, in the paper's table order.
    pub fn all() -> [Variant; 6] {
        [
            Variant::BtOff,
            Variant::BtSum,
            Variant::BtSumG128,
            Variant::VicOff,
            Variant::VicSum,
            Variant::VicSumG128,
        ]
    }

    /// Whether this is one of the proposed (FFT) regularizers.
    pub fn is_proposed(&self) -> bool {
        !matches!(self, Variant::BtOff | Variant::VicOff)
    }
}

/// Full training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Artifact preset name ("tiny" | "small" | "e2e") — must match an
    /// emitted `train_<variant>_<preset>` artifact.
    pub preset: String,
    /// The typed loss specification. Everything loss-derived (artifact
    /// ids, residual family, labels) comes from here.
    pub spec: LossSpec,
    /// Number of epochs.
    pub epochs: usize,
    /// Steps per epoch.
    pub steps_per_epoch: usize,
    /// Base learning rate (scaled by the warmup+cosine schedule).
    pub lr: f32,
    /// Linear warmup epochs.
    pub warmup_epochs: usize,
    /// Master seed (dataset, augmentations, permutations, init).
    pub seed: u64,
    /// Permute features every batch (§4.3). Ablation switch.
    pub permute: bool,
    /// Data-loader worker threads.
    pub loader_workers: usize,
    /// Prefetch queue depth.
    pub prefetch: usize,
    /// Virtual dataset size (indices wrap).
    pub epoch_size: u64,
    /// Artifact directory.
    pub artifact_dir: String,
    /// Output directory (metrics, checkpoints).
    pub out_dir: String,
    /// Log every k steps.
    pub log_every: usize,
    /// Extra raw artifact-name suffix appended after the spec fragment.
    /// Legacy escape hatch (the Table-11 runs used `"_q1"` here before
    /// `q` became part of the spec); prefer expressing `q` in the spec,
    /// which derives the same artifact names.
    pub artifact_suffix: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "tiny".into(),
            spec: Variant::BtSum.spec(),
            epochs: 2,
            steps_per_epoch: 20,
            lr: 0.2,
            warmup_epochs: 1,
            seed: 17,
            permute: true,
            loader_workers: 2,
            prefetch: 4,
            epoch_size: 4096,
            artifact_dir: "artifacts".into(),
            out_dir: "runs/default".into(),
            log_every: 10,
            artifact_suffix: String::new(),
        }
    }
}

impl TrainConfig {
    /// Smallest runnable config (unit/integration tests).
    pub fn preset_tiny() -> TrainConfig {
        TrainConfig::default()
    }

    /// The end-to-end training preset (~2.4 M params, d=2048).
    pub fn preset_e2e() -> TrainConfig {
        TrainConfig {
            preset: "e2e".into(),
            epochs: 10,
            steps_per_epoch: 40,
            lr: 0.25,
            warmup_epochs: 2,
            epoch_size: 5120,
            out_dir: "runs/e2e".into(),
            ..TrainConfig::default()
        }
    }

    /// Mid-size preset for ablations.
    pub fn preset_small() -> TrainConfig {
        TrainConfig {
            preset: "small".into(),
            epochs: 6,
            steps_per_epoch: 30,
            lr: 0.25,
            warmup_epochs: 1,
            epoch_size: 2048,
            out_dir: "runs/small".into(),
            ..TrainConfig::default()
        }
    }

    /// Look up a named preset (case-insensitive).
    pub fn preset(name: &str) -> Result<TrainConfig> {
        Ok(match name.trim().to_ascii_lowercase().as_str() {
            "tiny" => Self::preset_tiny(),
            "small" => Self::preset_small(),
            "e2e" => Self::preset_e2e(),
            other => bail!("unknown preset '{other}' (valid: tiny, small, e2e)"),
        })
    }

    /// Apply a parsed TOML document (section "train" or top level).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        for (key, value) in doc.section("train").chain(doc.section("")) {
            self.apply_kv(key, &value.to_string_raw())?;
        }
        Ok(())
    }

    /// Apply CLI overrides (consumes the known flags).
    pub fn apply_args(&mut self, args: &mut Args) -> Result<()> {
        for key in [
            "preset",
            "variant",
            "spec",
            "epochs",
            "steps-per-epoch",
            "lr",
            "warmup-epochs",
            "seed",
            "permute",
            "loader-workers",
            "prefetch",
            "epoch-size",
            "artifact-dir",
            "out-dir",
            "log-every",
        ] {
            if let Some(v) = args.flag(key) {
                if key == "preset" {
                    // preset re-bases everything, then later flags override
                    let keep_spec = self.spec;
                    *self = TrainConfig::preset(&v)?;
                    self.spec = keep_spec;
                } else {
                    self.apply_kv(&key.replace('-', "_"), &v)?;
                }
            }
        }
        Ok(())
    }

    fn apply_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "preset" => self.preset = v.to_string(),
            // "variant" and "spec" are aliases: both accept the legacy
            // fragments and the full spec grammar.
            "variant" | "spec" => self.spec = LossSpec::parse(v)?,
            "epochs" => self.epochs = v.parse()?,
            "steps_per_epoch" => self.steps_per_epoch = v.parse()?,
            "lr" => self.lr = v.parse()?,
            "warmup_epochs" => self.warmup_epochs = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "permute" => self.permute = v.parse()?,
            "loader_workers" => self.loader_workers = v.parse()?,
            "prefetch" => self.prefetch = v.parse()?,
            "epoch_size" => self.epoch_size = v.parse()?,
            "artifact_dir" => self.artifact_dir = v.to_string(),
            "out_dir" => self.out_dir = v.to_string(),
            "log_every" => self.log_every = v.parse()?,
            "artifact_suffix" => self.artifact_suffix = v.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Total optimizer steps.
    pub fn total_steps(&self) -> usize {
        self.epochs * self.steps_per_epoch
    }

    /// The spec fragment plus the legacy raw suffix — the variant part of
    /// every artifact id this config resolves.
    pub fn variant_fragment(&self) -> String {
        format!("{}{}", self.spec.artifact_fragment(), self.artifact_suffix)
    }

    /// The train artifact name for this config.
    pub fn train_artifact(&self) -> String {
        format!("train_{}_{}", self.variant_fragment(), self.preset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_roundtrip() {
        for v in Variant::all() {
            assert_eq!(Variant::parse(v.as_str()).unwrap(), v);
        }
        assert!(Variant::parse("nope").is_err());
        assert!(Variant::BtSum.is_proposed());
        assert!(!Variant::BtOff.is_proposed());
    }

    #[test]
    fn variant_parse_is_case_insensitive_and_reports_valid_set() {
        assert_eq!(Variant::parse("BT_SUM").unwrap(), Variant::BtSum);
        assert_eq!(Variant::parse("  Vic_Sum_G128 ").unwrap(), Variant::VicSumG128);
        let err = Variant::parse("nope").unwrap_err().to_string();
        for valid in ["bt_off", "bt_sum_g128", "vic_sum"] {
            assert!(err.contains(valid), "error should list '{valid}': {err}");
        }
    }

    #[test]
    fn presets_resolve() {
        assert_eq!(TrainConfig::preset("e2e").unwrap().preset, "e2e");
        assert_eq!(TrainConfig::preset("TINY").unwrap().preset, "tiny");
        let err = TrainConfig::preset("nope").unwrap_err().to_string();
        assert!(err.contains("tiny") && err.contains("small") && err.contains("e2e"), "{err}");
    }

    #[test]
    fn cli_overrides() {
        let mut args = Args::parse_from(
            ["train", "--epochs", "7", "--variant", "vic_sum", "--lr", "0.5"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_args(&mut args).unwrap();
        assert_eq!(cfg.epochs, 7);
        assert_eq!(cfg.spec, Variant::VicSum.spec());
        assert_eq!(cfg.lr, 0.5);
        args.finish().unwrap();
    }

    #[test]
    fn cli_accepts_spec_grammar() {
        let mut args = Args::parse_from(
            ["train", "--variant", "bt_sum@b=64,q=1"]
                .into_iter()
                .map(String::from),
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_args(&mut args).unwrap();
        assert_eq!(cfg.spec.artifact_fragment(), "bt_sum_g64_q1");
        assert_eq!(cfg.train_artifact(), "train_bt_sum_g64_q1_tiny");
        args.finish().unwrap();
    }

    #[test]
    fn toml_applies() {
        let doc = parse_toml(
            "[train]\nepochs = 3\nlr = 0.125\npermute = false\nvariant = \"bt_off\"\n",
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.epochs, 3);
        assert_eq!(cfg.lr, 0.125);
        assert!(!cfg.permute);
        assert_eq!(cfg.spec, Variant::BtOff.spec());
    }

    #[test]
    fn artifact_name() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.train_artifact(), "train_bt_sum_tiny");
        // the legacy raw-suffix escape hatch still composes
        let q1 = TrainConfig {
            artifact_suffix: "_q1".into(),
            ..TrainConfig::default()
        };
        assert_eq!(q1.train_artifact(), "train_bt_sum_q1_tiny");
        // … and the spec-native q derives the identical name
        let spec_q1 = TrainConfig {
            spec: LossSpec::parse("bt_sum@q=1").unwrap(),
            ..TrainConfig::default()
        };
        assert_eq!(spec_q1.train_artifact(), "train_bt_sum_q1_tiny");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = TrainConfig::default();
        assert!(cfg.apply_kv("bogus", "1").is_err());
    }
}
