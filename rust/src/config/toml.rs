//! A TOML-subset parser: `[section]` headers and `key = value` pairs with
//! string / integer / float / boolean values, `#` comments. Enough for the
//! coordinator's config files without external crates.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
}

impl TomlValue {
    /// The raw textual payload (strings unquoted) — config keys parse from
    /// this uniformly.
    pub fn to_string_raw(&self) -> String {
        match self {
            TomlValue::Str(s) => s.clone(),
            TomlValue::Int(i) => i.to_string(),
            TomlValue::Float(f) => f.to_string(),
            TomlValue::Bool(b) => b.to_string(),
        }
    }
}

/// A parsed document: `(section, key) → value`. Top-level keys use the
/// empty section name.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    /// Iterate `(key, value)` pairs of one section.
    pub fn section<'a>(
        &'a self,
        name: &'a str,
    ) -> impl Iterator<Item = (&'a str, &'a TomlValue)> + 'a {
        self.entries
            .iter()
            .filter(move |((s, _), _)| s == name)
            .map(|((_, k), v)| (k.as_str(), v))
    }

    /// Single-value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value'", lineno + 1);
        };
        let key = key.trim().to_string();
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entries.insert((section.clone(), key), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if let Some(rest) = v.strip_prefix('"') {
        let Some(s) = rest.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlValue::Str(s.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{v}'");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            "top = 1\n[train]\nlr = 0.3 # comment\nname = \"x # y\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("train", "lr"), Some(&TomlValue::Float(0.3)));
        assert_eq!(
            doc.get("train", "name"),
            Some(&TomlValue::Str("x # y".into()))
        );
        assert_eq!(doc.get("train", "flag"), Some(&TomlValue::Bool(true)));
    }

    #[test]
    fn section_iteration() {
        let doc = parse_toml("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        let a: Vec<_> = doc.section("a").map(|(k, _)| k).collect();
        assert_eq!(a, vec!["x", "y"]);
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse_toml("[oops\n").is_err());
        assert!(parse_toml("bare\n").is_err());
        assert!(parse_toml("x = \"unterminated\n").is_err());
        assert!(parse_toml("x = what\n").is_err());
    }

    #[test]
    fn raw_conversion() {
        assert_eq!(TomlValue::Int(5).to_string_raw(), "5");
        assert_eq!(TomlValue::Bool(false).to_string_raw(), "false");
        assert_eq!(TomlValue::Str("s".into()).to_string_raw(), "s");
    }
}
