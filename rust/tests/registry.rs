//! Integration tests for the cross-process compiled-artifact registry:
//! session source resolution from registry snapshots (the rank-worker /
//! repeat-CI path), graceful degradation on corrupt entries, warm-from-dir
//! idempotence, and gc interplay with name markers.
//!
//! Source resolution goes through `SharedSession` and never touches PJRT,
//! so most of these run everywhere; the end-to-end execute test needs a
//! PJRT client and skips without one, same as `tests/session.rs`.

use std::collections::BTreeSet;
use std::path::PathBuf;

use decorr::bench_harness::SynthArtifacts;
use decorr::runtime::{registry, Registry, SharedSession};

/// A registry under a fresh temp dir, removed by `TempRegistry::drop`.
struct TempRegistry {
    dir: PathBuf,
    reg: Registry,
}

impl TempRegistry {
    fn create(tag: &str) -> TempRegistry {
        let dir =
            std::env::temp_dir().join(format!("decorr_regtest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = Registry::open(&dir).unwrap();
        TempRegistry { dir, reg }
    }
}

impl Drop for TempRegistry {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[test]
fn warm_from_dir_is_idempotent_and_resolvable() {
    let synth = SynthArtifacts::generate("regwarm", &[(4, 16), (4, 32)]).unwrap();
    let tmp = TempRegistry::create("warm");

    let first = tmp.reg.warm_from_dir(&synth.dir).unwrap();
    assert_eq!(first.scanned, 2);
    assert_eq!(first.stored, 2);
    assert_eq!(first.malformed, 0);

    // Second warm over the same dir stores nothing new.
    let second = tmp.reg.warm_from_dir(&synth.dir).unwrap();
    assert_eq!(second.stored, 0);
    assert_eq!(second.skipped, 2);

    // Every name resolves to a healthy portable source entry.
    for name in &synth.names {
        let key = tmp.reg.resolve_name(name).expect("name marker");
        match tmp.reg.lookup(&key, registry::FP_PORTABLE) {
            registry::Lookup::Hit(entry) => {
                assert_eq!(entry.codec, registry::CODEC_SOURCE);
                assert_eq!(entry.name, *name);
                registry::decode_source(&entry.payload).unwrap();
            }
            registry::Lookup::Miss(m) => panic!("expected hit for {name}, got {m:?}"),
        }
    }
    let healthy = tmp.reg.inspect().unwrap();
    assert_eq!(healthy.len(), 2);
    assert!(healthy.iter().all(|e| e.corrupt.is_none()));
}

#[test]
fn session_resolves_sources_from_registry_without_artifact_dir() {
    let synth = SynthArtifacts::generate("regsrc", &[(4, 16), (8, 32)]).unwrap();
    let tmp = TempRegistry::create("src");
    tmp.reg.warm_from_dir(&synth.dir).unwrap();

    // A shared core over a directory that does not exist: every source
    // must come from the registry (zero artifact-dir reads).
    let missing = synth.dir.join("no-such-dir");
    let shared = SharedSession::open_with_registry(&missing, Some(tmp.reg.clone()));
    for name in &synth.names {
        let src = shared.source(name).unwrap();
        assert_eq!(&src.name, name);
        // The materialized HLO lives under the registry, not the
        // (nonexistent) artifact dir.
        assert!(src.hlo_path.starts_with(&tmp.dir));
    }
    let stats = shared.stats();
    assert_eq!(stats.registry_hits, synth.names.len() as u64);
    assert_eq!(stats.source_reads, 0);
    assert_eq!(stats.registry_misses, 0);

    // Repeat requests hit the in-process source cache, not the registry.
    shared.source(&synth.names[0]).unwrap();
    assert_eq!(shared.stats().registry_hits, synth.names.len() as u64);
}

#[test]
fn artifact_dir_wins_over_registry_when_both_resolve() {
    let synth = SynthArtifacts::generate("regdir", &[(4, 16)]).unwrap();
    let tmp = TempRegistry::create("dir");
    tmp.reg.warm_from_dir(&synth.dir).unwrap();

    let shared = SharedSession::open_with_registry(&synth.dir, Some(tmp.reg.clone()));
    let src = shared.source(&synth.names[0]).unwrap();
    assert!(src.hlo_path.starts_with(&synth.dir));
    let stats = shared.stats();
    assert_eq!(stats.source_reads, 1);
    assert_eq!(stats.registry_hits, 0);
}

#[test]
fn corrupt_entry_degrades_to_typed_miss_not_panic() {
    let synth = SynthArtifacts::generate("regcorrupt", &[(4, 16)]).unwrap();
    let tmp = TempRegistry::create("corrupt");
    tmp.reg.warm_from_dir(&synth.dir).unwrap();
    let name = &synth.names[0];
    let key = tmp.reg.resolve_name(name).unwrap();

    // Truncate the entry mid-payload: the checksum no longer verifies.
    let path = tmp.reg.entry_path(&key);
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    let missing = synth.dir.join("no-such-dir");
    let shared = SharedSession::open_with_registry(&missing, Some(tmp.reg.clone()));
    let err = shared.source(name).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("not resolvable from the registry"),
        "error should name the registry fallback: {msg}"
    );
    assert_eq!(shared.stats().registry_misses, 1);
    assert_eq!(shared.stats().registry_hits, 0);

    // `inspect` reports the entry as corrupt instead of erroring out.
    let summaries = tmp.reg.inspect().unwrap();
    assert_eq!(summaries.len(), 1);
    assert!(summaries[0].corrupt.is_some());

    // With the artifact dir back in the picture the same name resolves
    // fine — the corrupt registry never blocks a dir-backed load.
    let dir_shared = SharedSession::open_with_registry(&synth.dir, Some(tmp.reg.clone()));
    dir_shared.source(name).unwrap();
}

#[test]
fn gc_drops_unused_entries_and_dangling_name_markers() {
    let synth = SynthArtifacts::generate("reggc", &[(4, 16), (4, 32), (4, 64)]).unwrap();
    let tmp = TempRegistry::create("gc");
    tmp.reg.warm_from_dir(&synth.dir).unwrap();

    let keep_name = &synth.names[0];
    let keep_key = tmp.reg.resolve_name(keep_name).unwrap();
    let mut in_use = BTreeSet::new();
    in_use.insert(keep_key.clone());

    let report = tmp.reg.gc(&in_use).unwrap();
    assert_eq!(report.scanned, 3);
    assert_eq!(report.kept, 1);
    assert_eq!(report.removed, 2);
    assert!(report.bytes_freed > 0);

    // The kept entry still resolves; the collected names lost their
    // markers, so a no-dir session now misses them.
    assert_eq!(tmp.reg.resolve_name(keep_name).as_deref(), Some(&keep_key[..]));
    for name in &synth.names[1..] {
        assert!(tmp.reg.resolve_name(name).is_none(), "{name} should be gone");
    }

    let missing = synth.dir.join("no-such-dir");
    let shared = SharedSession::open_with_registry(&missing, Some(tmp.reg.clone()));
    shared.source(keep_name).unwrap();
    assert!(shared.source(&synth.names[1]).is_err());
}

/// End to end on a real PJRT client: publish by loading through a
/// dir-backed session, then compile-and-execute the same artifacts from a
/// registry-only session and compare outputs bit-exactly. Skips when no
/// PJRT client can be created, like the artifact-gated tests.
#[test]
fn registry_only_session_executes_identically() {
    let synth = SynthArtifacts::generate("regexec", &[(4, 16)]).unwrap();
    let tmp = TempRegistry::create("exec");

    let publisher = SharedSession::open_with_registry(&synth.dir, Some(tmp.reg.clone()));
    let pub_session = match publisher.session() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: no PJRT client ({e:#})");
            return;
        }
    };
    let name = &synth.names[0];
    let dir_artifact = pub_session.load(name).unwrap();
    let dir_value = SynthArtifacts::smoke(&dir_artifact).unwrap();
    assert_eq!(publisher.stats().registry_stores, 1);

    let missing = synth.dir.join("no-such-dir");
    let warm_shared = SharedSession::open_with_registry(&missing, Some(tmp.reg.clone()));
    let warm_session = warm_shared.session().unwrap();
    let warm_artifact = warm_session.load(name).unwrap();
    let warm_value = SynthArtifacts::smoke(&warm_artifact).unwrap();

    assert_eq!(dir_value.to_bits(), warm_value.to_bits());
    let stats = warm_shared.stats();
    assert_eq!(stats.registry_hits, 1);
    assert_eq!(stats.source_reads, 0);
    if registry::exe_codec::supported() {
        assert_eq!(stats.compiles, 0, "warm run must reuse the stored executable");
    } else {
        assert_eq!(stats.compiles, 1, "source snapshot degrades to one recompile");
    }
}
