//! Compat tests for the `api` front door: every legacy `Variant`
//! constructs via `LossSpec` and produces bit-identical losses and
//! identical artifact ids to the pre-redesign code, while specs outside
//! the closed enum derive kernels, labels, and artifact ids with no new
//! enum members.

use decorr::api::{
    Backend, HostExecutor, LossExecutor, LossFamily, LossSpec, RegularizerForm, SpecError,
};
use decorr::bench_harness::Contender;
use decorr::config::{TrainConfig, Variant};
use decorr::regularizer::kernel::{
    DecorrelationKernel, FftSumvecKernel, GroupedFftKernel, NaiveMatrixKernel,
};
use decorr::regularizer::{self, Q};
use decorr::util::rng::Rng;
use decorr::util::tensor::Tensor;

fn rand_views(seed: u64, n: usize, d: usize) -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    (
        Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.gaussian()).collect()),
        Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.gaussian()).collect()),
    )
}

/// The pre-redesign artifact-id derivations, written out longhand.
#[test]
fn legacy_variants_derive_identical_artifact_ids() {
    for v in Variant::all() {
        let spec = v.spec();
        for preset in ["tiny", "small", "e2e"] {
            // train_<variant>_<preset> — the legacy TrainConfig scheme.
            assert_eq!(
                spec.train_artifact(preset),
                format!("train_{}_{preset}", v.as_str())
            );
            // grad_<variant>_<preset>_s<K> — the legacy DdpTrainer scheme.
            for shards in [1usize, 2, 4] {
                assert_eq!(
                    spec.grad_artifact(preset, shards),
                    format!("grad_{}_{preset}_s{shards}", v.as_str())
                );
            }
        }
        // loss_<variant>_d<d>_n<n> — the legacy LossWorkload scheme.
        assert_eq!(
            spec.loss_artifact(512, 128, false),
            format!("loss_{}_d512_n128", v.as_str())
        );
        assert_eq!(
            spec.loss_artifact(2048, 64, true),
            format!("lossgrad_{}_d2048_n64", v.as_str())
        );
        // …and the full config path agrees with the legacy string.
        let cfg = TrainConfig {
            spec,
            ..TrainConfig::default()
        };
        assert_eq!(cfg.train_artifact(), format!("train_{}_tiny", v.as_str()));
    }
}

/// The table-11 q-suffix scheme: spec-native q derives the same ids the
/// legacy `artifact_suffix` escape hatch produced.
#[test]
fn q_suffix_ids_match_legacy_suffix_mechanism() {
    let pairs = [
        ("bt_sum@q=1", "bt_sum", "_q1"),
        ("vic_sum@q=2", "vic_sum", "_q2"),
    ];
    for (spec_str, variant, suffix) in pairs {
        let spec = LossSpec::parse(spec_str).unwrap();
        assert_eq!(spec.artifact_fragment(), format!("{variant}{suffix}"));
        let legacy = TrainConfig {
            spec: Variant::parse(variant).unwrap().spec(),
            artifact_suffix: suffix.to_string(),
            ..TrainConfig::default()
        };
        let modern = TrainConfig {
            spec,
            ..TrainConfig::default()
        };
        assert_eq!(modern.train_artifact(), legacy.train_artifact());
    }
}

/// Bit-identical host losses: the spec-derived kernels are the same
/// concrete kernels the pre-redesign call sites constructed by hand, so
/// the values must be exactly equal (f64 ==), not merely close.
#[test]
fn legacy_variants_produce_bit_identical_losses() {
    let (n, d) = (32usize, 256usize); // 128 | 256 so g128 presets resolve
    let (a, b) = rand_views(0xA11CE, n, d);
    let norm_bt = n as f32;
    let norm_vic = (n as f32 - 1.0).max(1.0);
    for v in Variant::all() {
        let spec = v.spec();
        let mut kernel = spec.kernel(d).unwrap();
        kernel.accumulate(&a, &b);
        let norm = if spec.family == LossFamily::VicReg {
            norm_vic
        } else {
            norm_bt
        };
        match v {
            Variant::BtOff | Variant::VicOff => {
                let mut legacy = NaiveMatrixKernel::new(d);
                legacy.accumulate(&a, &b);
                assert_eq!(
                    kernel.r_off(norm).unwrap(),
                    legacy.r_off(norm).unwrap(),
                    "{v:?}"
                );
            }
            Variant::BtSum | Variant::VicSum => {
                let mut legacy = FftSumvecKernel::new(d);
                legacy.accumulate(&a, &b);
                assert_eq!(
                    kernel.r_sum(norm, spec.q()),
                    legacy.r_sum(norm, spec.q()),
                    "{v:?}"
                );
            }
            Variant::BtSumG128 | Variant::VicSumG128 => {
                let mut legacy = GroupedFftKernel::new(d, 128);
                legacy.accumulate(&a, &b);
                assert_eq!(
                    kernel.r_sum(norm, spec.q()),
                    legacy.r_sum(norm, spec.q()),
                    "{v:?}"
                );
            }
        }
    }
}

/// The host executor's BT composition is bit-identical to the legacy
/// free-function composition.
#[test]
fn host_executor_matches_legacy_bt_loss() {
    let (n, d) = (48usize, 32usize);
    let (a, b) = rand_views(7, n, d);
    for (q, lambda) in [(Q::L2, 2f32.powi(-10)), (Q::L1, 0.0051f32)] {
        let spec = LossSpec::builder(LossFamily::BarlowTwins)
            .sum(q)
            .lambda(lambda)
            .build()
            .unwrap();
        let mut exec = HostExecutor::new(&spec, d).unwrap();
        assert_eq!(exec.backend(), Backend::Host);
        let out = exec.evaluate(&a, &b).unwrap();
        assert_eq!(
            out.total,
            regularizer::barlow_twins_sum_loss(&a, &b, lambda, q),
            "q={q:?}"
        );
    }
}

/// Specs outside the closed enum: the ISSUE's acceptance examples derive
/// everything the legacy presets do, with no new enum members.
#[test]
fn beyond_enum_specs_are_first_class() {
    let g64 = LossSpec::parse("bt_sum@b=64,q=1").unwrap();
    assert_eq!(g64.legacy_variant(), None);
    assert_eq!(g64.artifact_fragment(), "bt_sum_g64_q1");
    assert_eq!(g64.train_artifact("small"), "train_bt_sum_g64_q1_small");
    assert_eq!(
        g64.form,
        RegularizerForm::GroupedSum { q: Q::L1, block: 64 }
    );

    let g256 = LossSpec::parse("vic_sum@b=256,q=2").unwrap();
    assert_eq!(g256.legacy_variant(), None);
    assert_eq!(g256.artifact_fragment(), "vic_sum_g256_q2");
    assert_eq!(g256.display_name(), "Proposed (VIC-style, b=256, q=2)");

    // Both run as bench contenders and agree with the directly-driven
    // kernels, bit for bit.
    let (n, d) = (16usize, 256usize);
    let (a, b) = rand_views(99, n, d);
    for spec in [g64, g256] {
        let mut contender = Contender::from_spec(&spec, d).unwrap();
        let got = contender.run(&a, &b, n as f32);
        let mut kernel = GroupedFftKernel::new(d, spec.form.block().unwrap());
        kernel.accumulate(&a, &b);
        assert_eq!(got, kernel.r_sum(n as f32, spec.q()), "{spec}");
        // config layer accepts them through the ordinary --variant path
        let mut cfg = TrainConfig::default();
        cfg.apply_args(
            &mut decorr::util::cli::Args::parse_from(
                ["train", "--variant", &spec.to_string()]
                    .into_iter()
                    .map(String::from),
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.spec, spec);
    }
}

/// The strict host-side grouping contract: contenders and kernels reject
/// blocks that do not divide d with a typed error.
#[test]
fn spec_validation_is_typed() {
    let g = LossSpec::parse("bt_sum@b=64").unwrap();
    match Contender::from_spec(&g, 100) {
        Err(e) => assert_eq!(e, SpecError::BlockMismatch { block: 64, d: 100 }),
        Ok(_) => panic!("64 does not divide 100"),
    }
    match g.host_executor(100) {
        Err(e) => assert_eq!(e, SpecError::BlockMismatch { block: 64, d: 100 }),
        Ok(_) => panic!("64 does not divide 100"),
    }
    assert!(Contender::from_spec(&g, 128).is_ok());
    match LossSpec::parse("bt_off").unwrap().kernel(1) {
        Err(e) => assert_eq!(e, SpecError::DimTooSmall { d: 1 }),
        Ok(_) => panic!("d=1 must be rejected"),
    }
}

/// Labels derived from specs match the legacy hard-coded tables.
#[test]
fn display_names_match_legacy_table() {
    let expected = [
        (Variant::BtOff, "Barlow Twins (R_off)"),
        (Variant::BtSum, "Proposed (BT-style)"),
        (Variant::BtSumG128, "Proposed (BT-style, b=128)"),
        (Variant::VicOff, "VICReg (R_off)"),
        (Variant::VicSum, "Proposed (VIC-style)"),
        (Variant::VicSumG128, "Proposed (VIC-style, b=128)"),
    ];
    for (v, name) in expected {
        assert_eq!(v.spec().display_name(), name);
        assert_eq!(decorr::bench_harness::cmd::display_name(v), name);
    }
}
