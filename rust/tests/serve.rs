//! Integration tests for the serving subsystem: a real server on a real
//! socket, driven by real protocol clients.
//!
//! The two load-bearing properties, both pinned bit-exactly:
//!
//! * micro-batched responses equal single-request host-executor results
//!   (`to_bits` equality, not a tolerance), and
//! * graceful drain returns every in-flight response before `join`.

use decorr::api::{LossExecutor, LossSpec};
use decorr::serve::exec::RowScorer;
use decorr::serve::{
    serve, ExecMode, Request, RequestKind, Response, ServeAddr, ServeClient, ServeConfig,
    ServerHandle,
};
use decorr::util::rng::Rng;
use decorr::util::tensor::Tensor;
use std::time::Duration;

/// Per-test unix-socket address (pid + tag keeps parallel runs apart).
fn unix_addr(tag: &str) -> ServeAddr {
    ServeAddr::Unix(
        std::env::temp_dir().join(format!("decorr-serve-test-{}-{tag}.sock", std::process::id())),
    )
}

fn host_server(addr: ServeAddr, batch_rows: usize, deadline: Duration) -> ServerHandle {
    serve(ServeConfig {
        addr,
        workers: 2,
        batch_rows,
        deadline,
        mode: ExecMode::Host,
        ..ServeConfig::default()
    })
    .expect("server binds")
}

fn score_request(id: u64, spec: &str, rows: usize, d: usize, rng: &mut Rng) -> Request {
    Request {
        id,
        kind: RequestKind::Score,
        spec: spec.to_string(),
        rows,
        d,
        a: (0..rows * d).map(|_| rng.gaussian()).collect(),
        b: (0..rows * d).map(|_| rng.gaussian()).collect(),
    }
}

/// Concurrent clients force real coalescing (batch of 8 rows, 3-row
/// requests), and every response must still be bit-identical to scoring
/// that request alone — the padding/scatter path cannot perturb results.
#[test]
fn microbatched_scores_bit_identical_to_single_request() {
    let handle = host_server(unix_addr("batch"), 8, Duration::from_millis(1));
    let addr = handle.local_addr().clone();
    let (rows, d) = (3usize, 16usize);
    let spec = LossSpec::parse("bt_sum").unwrap();
    let threads: Vec<_> = (0..4u64)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = ServeClient::connect(&addr).expect("connect");
                let mut rng = Rng::new(0xBA7C4 + t);
                let mut oracle = RowScorer::new(d, spec.q());
                for i in 0..6u64 {
                    let req = score_request(t * 100 + i, "bt_sum", rows, d, &mut rng);
                    let resp = client.call(&req).expect("call");
                    let Response::Score { id, scores } = resp else {
                        panic!("expected Score, got {resp:?}");
                    };
                    assert_eq!(id, req.id);
                    assert_eq!(scores.len(), rows);
                    let want = oracle.score_rows(rows, &req.a, &req.b);
                    for (r, (got, want)) in scores.iter().zip(&want).enumerate() {
                        assert_eq!(got.score.to_bits(), want.score.to_bits(), "row {r}");
                        assert_eq!(got.align.to_bits(), want.align.to_bits(), "row {r}");
                    }
                }
                client.finish_sending().ok();
            })
        })
        .collect();
    for t in threads {
        t.join().expect("client thread");
    }
    let report = handle.join().expect("join");
    assert_eq!(report.stats.total_requests(), 24);
    assert_eq!(report.stats.total_errors(), 0);
    assert_eq!(report.stats.connections, 4);
}

/// A diagnose response equals evaluating the same matrices through the
/// spec's `HostExecutor` directly, bit for bit.
#[test]
fn diagnose_bit_identical_to_host_executor() {
    let handle = host_server(unix_addr("diag"), 32, Duration::from_millis(1));
    let mut client = ServeClient::connect(handle.local_addr()).expect("connect");
    let (rows, d) = (8usize, 12usize);
    let mut rng = Rng::new(0xD1A6);
    for spec_str in ["bt_sum", "vic_sum"] {
        let mut req = score_request(7, spec_str, rows, d, &mut rng);
        req.kind = RequestKind::Diagnose;
        let resp = client.call(&req).expect("call");
        let Response::Diagnose {
            id,
            total,
            invariance,
            regularizer,
            ..
        } = resp
        else {
            panic!("expected Diagnose, got {resp:?}");
        };
        assert_eq!(id, 7);
        let spec = LossSpec::parse(spec_str).unwrap();
        let mut direct = spec.host_executor(d).unwrap();
        let want = direct
            .evaluate(
                &Tensor::from_vec(&[rows, d], req.a.clone()),
                &Tensor::from_vec(&[rows, d], req.b.clone()),
            )
            .unwrap();
        assert_eq!(total.to_bits(), want.total.to_bits(), "{spec_str}");
        assert_eq!(
            invariance.map(f64::to_bits),
            want.invariance.map(f64::to_bits),
            "{spec_str}"
        );
        assert_eq!(
            regularizer.map(f64::to_bits),
            want.regularizer.map(f64::to_bits),
            "{spec_str}"
        );
    }
    client.finish_sending().ok();
    drop(client);
    handle.join().expect("join");
}

/// Requests parked behind a far-off deadline are flushed by the drain:
/// every in-flight response arrives before `join` returns.
#[test]
fn graceful_drain_returns_every_inflight_response() {
    // 64-row batch + 10 s deadline: five 2-row requests can only be
    // answered by the drain flush, never by fill or deadline.
    let handle = host_server(unix_addr("drain"), 64, Duration::from_secs(10));
    let mut client = ServeClient::connect(handle.local_addr()).expect("connect");
    let (rows, d) = (2usize, 8usize);
    let mut rng = Rng::new(0xD3A1);
    let reqs: Vec<Request> = (1..=5u64)
        .map(|id| score_request(id, "bt_sum", rows, d, &mut rng))
        .collect();
    for req in &reqs {
        client.send(req).expect("send");
    }
    client.finish_sending().expect("finish");
    handle.shutdown();
    let mut seen: Vec<u64> = Vec::new();
    for _ in 0..reqs.len() {
        let resp = client.recv().expect("drained response");
        match resp {
            Response::Score { id, scores } => {
                assert_eq!(scores.len(), rows);
                seen.push(id);
            }
            other => panic!("expected Score, got {other:?}"),
        }
    }
    seen.sort_unstable();
    assert_eq!(seen, vec![1, 2, 3, 4, 5]);
    let report = handle.join().expect("join");
    assert_eq!(report.stats.total_requests(), 5);
    // The flush that answered them was the drain, and the tables carry
    // the serving columns the bench-diff gate classifies.
    let batches = report.stats.batch_table().render();
    assert!(batches.contains("drain_flushes"), "{batches}");
    let latency = report.stats.latency_table().render();
    for col in ["p50_latency_ms", "p95_latency_ms", "p99_latency_ms"] {
        assert!(latency.contains(col), "{latency}");
    }
}

/// Request-scoped failures answer with a typed error and the connection
/// survives; a framing failure answers id 0 and closes it.
#[test]
fn unknown_spec_errors_then_connection_survives() {
    let handle = host_server(unix_addr("err"), 8, Duration::from_millis(1));
    let mut client = ServeClient::connect(handle.local_addr()).expect("connect");
    let mut rng = Rng::new(0xE44);

    // Unknown spec: typed error echoing the id, connection stays up.
    let bad = score_request(11, "definitely_not_a_spec", 2, 8, &mut rng);
    match client.call(&bad).expect("error response") {
        Response::Error { id, code, message } => {
            assert_eq!(id, 11);
            assert!(code > 0);
            assert!(message.contains("definitely_not_a_spec"), "{message}");
        }
        other => panic!("expected Error, got {other:?}"),
    }

    // Same connection immediately serves a valid request.
    let good = score_request(12, "bt_sum", 2, 8, &mut rng);
    match client.call(&good).expect("valid response") {
        Response::Score { id, scores } => {
            assert_eq!(id, 12);
            assert_eq!(scores.len(), 2);
        }
        other => panic!("expected Score, got {other:?}"),
    }

    // A corrupt magic is a framing error: the server answers id 0 and
    // hangs up on this connection.
    client.send_raw(b"XXXX\x04\x00\x00\x00abcd").expect("raw");
    match client.recv().expect("framing error response") {
        Response::Error { id, .. } => assert_eq!(id, 0),
        other => panic!("expected Error, got {other:?}"),
    }
    drop(client);

    let report = handle.join().expect("join");
    assert_eq!(report.stats.framing_errors, 1);
    assert_eq!(report.stats.total_errors(), 1);
    assert_eq!(report.stats.total_requests(), 1);
}

/// The TCP path works end to end on an ephemeral loopback port (the unix
/// path is exercised by every other test here).
#[test]
fn tcp_ephemeral_port_serves() {
    let handle = host_server(ServeAddr::parse("127.0.0.1:0"), 8, Duration::from_millis(1));
    let mut client = ServeClient::connect(handle.local_addr()).expect("connect");
    let mut rng = Rng::new(0x7C9);
    let req = score_request(1, "vic_off", 4, 8, &mut rng);
    match client.call(&req).expect("call") {
        Response::Score { id, scores } => {
            assert_eq!(id, 1);
            assert_eq!(scores.len(), 4);
        }
        other => panic!("expected Score, got {other:?}"),
    }
    client.finish_sending().ok();
    drop(client);
    handle.join().expect("join");
}
