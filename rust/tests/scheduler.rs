//! Determinism and concurrency tests for the parallel sweep scheduler.
//!
//! The scheduler's core promise: `--parallel K` changes wall-clock only.
//! Per-spec losses are bit-identical between serial and parallel sweeps,
//! the merged output is spec-sorted (so `BENCH_spec_grid.json` rows are
//! identical modulo timing fields), and in train mode every worker arm
//! of the one `SharedSession` compiles each distinct shape it executes
//! exactly once. Host-mode tests need no artifacts; the session stress
//! test generates synthetic HLO and skips without a PJRT client; the
//! train-mode tests gate on `make artifacts` like `tests/driver.rs`.

use decorr::api::train::{SweepMode, SweepPlan, SweepScheduler};
use decorr::api::LossSpec;
use decorr::bench_harness::SynthArtifacts;
use decorr::config::TrainConfig;
use decorr::runtime::SharedSession;
use decorr::util::json::{self, Json};

fn host_mode(d: usize, n: usize) -> SweepMode {
    SweepMode::Host { d, n, budget: 0.0 }
}

/// Parallel and serial host sweeps agree bit-for-bit on every spec value
/// and produce identically ordered grids.
#[test]
fn parallel_and_serial_host_sweeps_are_bit_identical() {
    let plan = SweepPlan::parse("bt_sum@b={64,128},q={1,2};vic_sum;bt_off").unwrap();
    assert_eq!(plan.len(), 6);
    let serial = SweepScheduler::new(plan.clone(), host_mode(256, 32))
        .workers(1)
        .run()
        .unwrap();
    let parallel = SweepScheduler::new(plan, host_mode(256, 32))
        .workers(4)
        .run()
        .unwrap();
    assert_eq!(serial.results.len(), parallel.results.len());
    for (s, p) in serial.results.iter().zip(&parallel.results) {
        assert_eq!(s.report.spec, p.report.spec, "grid order diverged");
        assert_eq!(
            s.report.final_loss.to_bits(),
            p.report.final_loss.to_bits(),
            "host loss bits diverged for {}",
            s.report.spec
        );
        assert_eq!(
            s.report.initial_loss.to_bits(),
            p.report.initial_loss.to_bits()
        );
    }
    // Worker attribution stays within the requested pool. (Whether the
    // jobs actually spread across workers depends on OS scheduling — a
    // fast grid can drain before every thread spawns — so spread itself
    // is not asserted.)
    assert!(parallel.results.iter().all(|r| r.worker < 4));
}

/// The emitted `BENCH_spec_grid.json` rows are identical between serial
/// and parallel sweeps, modulo the timing fields.
#[test]
fn spec_grid_json_is_identical_modulo_timing() {
    let plan = SweepPlan::parse("bt_sum@b={32,64};vic_sum@q=2").unwrap();
    let dir = std::env::temp_dir().join(format!("decorr_sched_json_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let serial_path = dir.join("serial.json");
    let parallel_path = dir.join("parallel.json");
    SweepScheduler::new(plan.clone(), host_mode(128, 16))
        .workers(1)
        .run()
        .unwrap()
        .write_json(serial_path.to_str().unwrap())
        .unwrap();
    SweepScheduler::new(plan, host_mode(128, 16))
        .workers(3)
        .run()
        .unwrap()
        .write_json(parallel_path.to_str().unwrap())
        .unwrap();

    let parse = |p: &std::path::Path| -> Vec<Json> {
        let doc = json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        doc.get("spec_grid")
            .and_then(|t| t.get("rows"))
            .and_then(Json::as_arr)
            .unwrap()
            .to_vec()
    };
    let (serial_rows, parallel_rows) = (parse(&serial_path), parse(&parallel_path));
    assert_eq!(serial_rows.len(), 3);
    assert_eq!(serial_rows.len(), parallel_rows.len());
    // Timing fields (steps, wall_seconds, steps_per_sec) vary run to
    // run; identity and value fields must match exactly.
    for (s, p) in serial_rows.iter().zip(&parallel_rows) {
        for field in ["spec", "initial_loss", "final_loss"] {
            assert_eq!(s.get(field), p.get(field), "field '{field}' diverged");
        }
    }
    // Rows are spec-sorted.
    let specs: Vec<&str> = serial_rows
        .iter()
        .map(|r| r.get("spec").and_then(Json::as_str).unwrap())
        .collect();
    let mut sorted = specs.clone();
    sorted.sort();
    assert_eq!(specs, sorted);
    std::fs::remove_dir_all(&dir).ok();
}

/// Concurrent-arm stress: K worker threads each take their own `Session`
/// arm over one `SharedSession` and load every name (3 distinct shapes,
/// each also aliased). Sources are read once process-wide; every arm
/// compiles each distinct shape exactly once (aliases are hits); the
/// cross-arm stats aggregate all of it.
#[test]
fn concurrent_arms_compile_each_shape_once_per_arm() {
    const WORKERS: usize = 4;
    let synth = SynthArtifacts::generate("sched_arms", &[(4, 16), (4, 32), (4, 64)]).unwrap();
    for name in &synth.names {
        synth.alias(name, &format!("{name}_alias")).unwrap();
    }
    let mut all_names: Vec<String> = synth.names.clone();
    all_names.extend(synth.names.iter().map(|n| format!("{n}_alias")));
    let shared = SharedSession::open(&synth.dir);
    // Probe once for PJRT availability before spawning the fleet.
    match shared.session() {
        Ok(_) => {}
        Err(e) => {
            eprintln!("skipping: no PJRT client ({e:#})");
            return;
        }
    }
    let probe_arms = shared.stats().arms;
    assert_eq!(probe_arms, 1);

    std::thread::scope(|scope| {
        for _ in 0..WORKERS {
            let shared = shared.clone();
            let names = all_names.clone();
            scope.spawn(move || {
                let arm = shared.session().expect("arm creation");
                for name in &names {
                    arm.load(name).expect("load");
                }
                // A second pass over everything is all hits on this arm.
                for name in &names {
                    arm.load(name).expect("reload");
                }
            });
        }
    });

    let stats = shared.stats();
    assert_eq!(stats.arms, 1 + WORKERS as u64, "probe + one arm per worker");
    // 3 distinct shapes × one compile per worker arm; everything else
    // (aliases + second pass) answered from the per-arm caches.
    assert_eq!(stats.compiles, (WORKERS * 3) as u64);
    assert_eq!(stats.loads, (WORKERS * 12) as u64);
    assert_eq!(stats.hits, stats.loads - stats.compiles);
    // The 6 files were read + parsed + hashed exactly once process-wide.
    assert_eq!(stats.source_reads, 6);
}

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/train_bt_sum_tiny.manifest.json").exists()
}

fn present_tiny_specs() -> Vec<LossSpec> {
    ["bt_sum", "bt_off", "vic_sum", "vic_off"]
        .iter()
        .filter_map(|s| LossSpec::parse(s).ok())
        .filter(|spec| {
            std::path::Path::new(&format!(
                "artifacts/{}.manifest.json",
                spec.train_artifact("tiny")
            ))
            .exists()
        })
        .collect()
}

/// Train-mode determinism: a parallel sweep over per-thread session arms
/// reproduces the serial sweep's per-spec losses bit-for-bit.
#[test]
fn parallel_train_sweep_matches_serial_bitwise() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let specs = present_tiny_specs();
    if specs.len() < 2 {
        eprintln!("skipping: need >= 2 tiny train artifacts");
        return;
    }
    let grid = specs
        .iter()
        .map(|s| s.to_string())
        .collect::<Vec<_>>()
        .join(";");
    let plan = SweepPlan::parse(&grid).unwrap();
    let mut base = TrainConfig::preset_tiny();
    base.epochs = 1;
    base.steps_per_epoch = 3;
    base.out_dir = String::new();
    // Single-threaded loader: multi-worker loaders may deliver batches
    // out of index order, which would break run-to-run bit-identity for
    // reasons unrelated to the scheduler.
    base.loader_workers = 1;
    base.log_every = usize::MAX;
    let mode = SweepMode::Train {
        base,
        shards: 0,
    };
    let serial = SweepScheduler::new(plan.clone(), mode.clone())
        .workers(1)
        .run()
        .unwrap();
    let parallel = SweepScheduler::new(plan, mode)
        .workers(specs.len())
        .run()
        .unwrap();
    assert_eq!(serial.results.len(), parallel.results.len());
    for (s, p) in serial.results.iter().zip(&parallel.results) {
        assert_eq!(s.report.spec, p.report.spec);
        assert_eq!(
            s.report.final_loss.to_bits(),
            p.report.final_loss.to_bits(),
            "train loss bits diverged for {}",
            s.report.spec
        );
    }
    // Cross-arm stats: the parallel sweep handed out one arm per worker
    // and compiled at least one shape per distinct spec.
    let stats = parallel.session_stats.expect("train mode reports stats");
    assert_eq!(stats.arms, parallel.workers as u64);
    assert!(stats.compiles >= specs.len() as u64);
}
