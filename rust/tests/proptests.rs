//! Property-based tests over the coordinator substrates.
//!
//! The offline environment has no `proptest` crate, so this file carries a
//! small property harness (`for_cases`) driving the crate's deterministic
//! RNG: each property is checked over many randomized cases and failures
//! report the case seed for exact reproduction.

use decorr::api::{LossFamily, LossSpec, NormConvention, RegularizerForm};
use decorr::config::{TrainConfig, Variant};
use decorr::coordinator::LrSchedule;
use decorr::data::loader::make_batch;
use decorr::data::shard::{ShardReader, ShardWriter};
use decorr::data::synth::{ShapeWorld, ShapeWorldConfig, Vocab};
use decorr::data::{AugmentConfig, Augmenter, Sample};
use decorr::fft;
use decorr::regularizer::kernel::{
    DecorrelationKernel, FftSumvecKernel, GroupedFftKernel, NaiveMatrixKernel,
};
use decorr::regularizer::{self, Q};
use decorr::serve::exec::SpecExecCache;
use decorr::serve::protocol::{
    decode_request_body, decode_response_body, encode_request, encode_response, read_frame,
    Request, RequestKind, RespondedBy, Response, RowScore, ServeError, MAX_FRAME, REQ_MAGIC,
};
use decorr::util::json;
use decorr::util::rng::Rng;
use decorr::util::tensor::Tensor;

/// Run `prop` over `cases` seeded random cases; panic with the seed on
/// failure so the case can be replayed.
fn for_cases(cases: u64, prop: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_tensor(rng: &mut Rng, n: usize, d: usize) -> Tensor {
    Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.gaussian()).collect())
}

// ---------------------------------------------------------------- sumvec

/// sumvec computed via FFT == sumvec computed from the materialized matrix,
/// across random shapes (the paper's Eq. 5 ≡ Eq. 12 identity).
#[test]
fn prop_sumvec_fft_equals_naive() {
    for_cases(40, |rng| {
        let n = 1 + rng.next_bounded(12) as usize;
        let d = 2 + rng.next_bounded(40) as usize;
        let a = rand_tensor(rng, n, d);
        let b = rand_tensor(rng, n, d);
        let c = regularizer::cross_correlation(&a, &b, n as f32);
        let naive = regularizer::sumvec_naive(&c);
        let fast = regularizer::sumvec_fft(&a, &b, n as f32);
        for (i, (x, y)) in naive.iter().zip(&fast).enumerate() {
            assert!(
                (x - y).abs() < 1e-3 * (1.0 + x.abs()),
                "n={n} d={d} i={i}: {x} vs {y}"
            );
        }
    });
}

/// Every element of C contributes to exactly one sumvec component.
#[test]
fn prop_sumvec_partitions_matrix() {
    for_cases(40, |rng| {
        let d = 2 + rng.next_bounded(32) as usize;
        let m = rand_tensor(rng, d, d);
        let sv = regularizer::sumvec_naive(&m);
        let total: f32 = m.data().iter().sum();
        let sv_total: f32 = sv.iter().sum();
        assert!((total - sv_total).abs() < 1e-3 * (1.0 + total.abs()));
    });
}

/// R_off is invariant under simultaneous feature permutation of both views;
/// the trace component of sumvec is too.
#[test]
fn prop_r_off_permutation_invariant() {
    for_cases(30, |rng| {
        let n = 4 + rng.next_bounded(12) as usize;
        let d = 3 + rng.next_bounded(20) as usize;
        let a = rand_tensor(rng, n, d);
        let b = rand_tensor(rng, n, d);
        let perm = rng.permutation(d);
        let c = regularizer::cross_correlation(&a, &b, n as f32);
        let cp = regularizer::cross_correlation(
            &a.permute_columns(&perm),
            &b.permute_columns(&perm),
            n as f32,
        );
        let off = regularizer::r_off(&c);
        let off_p = regularizer::r_off(&cp);
        assert!((off - off_p).abs() < 1e-3 * (1.0 + off.abs()));
        let tr = regularizer::sumvec_naive(&c)[0];
        let tr_p = regularizer::sumvec_naive(&cp)[0];
        assert!((tr - tr_p).abs() < 1e-3 * (1.0 + tr.abs()));
    });
}

/// Grouped regularizer interpolates: b=1,q=2 == R_off; b=d == R_sum.
#[test]
fn prop_grouping_interpolates() {
    for_cases(20, |rng| {
        let n = 3 + rng.next_bounded(8) as usize;
        let d = 4 + rng.next_bounded(12) as usize;
        let a = rand_tensor(rng, n, d);
        let b = rand_tensor(rng, n, d);
        let c = regularizer::cross_correlation(&a, &b, n as f32);
        let g1 = regularizer::r_sum_grouped_fft(&a, &b, 1, n as f32, Q::L2);
        let off = regularizer::r_off(&c);
        assert!((g1 - off).abs() < 1e-3 * (1.0 + off.abs()), "b=1: {g1} vs {off}");
        let gd = regularizer::r_sum_grouped_fft(&a, &b, d, n as f32, Q::L2);
        let flat = regularizer::r_sum_fft(&a, &b, n as f32, Q::L2);
        assert!((gd - flat).abs() < 1e-3 * (1.0 + flat.abs()), "b=d: {gd} vs {flat}");
    });
}

/// R_sum is never larger than d times R_off (Cauchy–Schwarz on each
/// wrap-diagonal sum of d elements), and both vanish together on diagonal C.
#[test]
fn prop_r_sum_bounded_by_r_off() {
    for_cases(30, |rng| {
        let n = 3 + rng.next_bounded(10) as usize;
        let d = 2 + rng.next_bounded(24) as usize;
        let a = rand_tensor(rng, n, d);
        let b = rand_tensor(rng, n, d);
        let c = regularizer::cross_correlation(&a, &b, n as f32);
        let r_sum = regularizer::r_sum_fft(&a, &b, n as f32, Q::L2);
        let r_off = regularizer::r_off(&c);
        assert!(
            r_sum <= d as f64 * r_off + 1e-3,
            "d={d}: r_sum {r_sum} > d*r_off {}",
            d as f64 * r_off
        );
    });
}

// ------------------------------------------------------------------- fft

/// FFT round-trip at random lengths (pow2 and not).
#[test]
fn prop_fft_roundtrip() {
    for_cases(40, |rng| {
        let n = 1 + rng.next_bounded(128) as usize;
        let x: Vec<fft::Complex> = (0..n)
            .map(|_| fft::Complex::new(rng.gaussian() as f64, rng.gaussian() as f64))
            .collect();
        let y = fft::ifft(&fft::fft(&x));
        for (a, b) in x.iter().zip(&y) {
            assert!((a.re - b.re).abs() < 1e-8 * n as f64 + 1e-9, "n={n}");
            assert!((a.im - b.im).abs() < 1e-8 * n as f64 + 1e-9, "n={n}");
        }
    });
}

/// Circular correlation linearity: corr(x, y1 + y2) = corr(x,y1) + corr(x,y2).
#[test]
fn prop_correlation_linear() {
    for_cases(30, |rng| {
        let d = 2 + rng.next_bounded(40) as usize;
        let x: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
        let y1: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
        let y2: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
        let sum: Vec<f32> = y1.iter().zip(&y2).map(|(a, b)| a + b).collect();
        let lhs = fft::circular_correlate(&x, &sum);
        let r1 = fft::circular_correlate(&x, &y1);
        let r2 = fft::circular_correlate(&x, &y2);
        for i in 0..d {
            assert!((lhs[i] - r1[i] - r2[i]).abs() < 1e-3, "d={d} i={i}");
        }
    });
}

// ----------------------------------------------------------- planned fft

/// Planned power-of-two transforms match the unplanned radix-2 path to
/// 1e-6, and the planned inverse round-trips.
#[test]
fn prop_planned_fft_matches_unplanned_pow2() {
    for_cases(30, |rng| {
        let n = 1usize << (1 + rng.next_bounded(8) as u32); // 2..512
        let x: Vec<fft::Complex> = (0..n)
            .map(|_| fft::Complex::new(rng.gaussian() as f64, rng.gaussian() as f64))
            .collect();
        let plan = fft::FftPlan::new(n);
        let mut scratch = plan.make_scratch();
        let mut planned = x.clone();
        plan.forward(&mut planned, &mut scratch);
        let mut reference = x.clone();
        fft::fft_pow2(&mut reference);
        for (i, (p, r)) in planned.iter().zip(&reference).enumerate() {
            assert!(
                (p.re - r.re).abs() < 1e-6 && (p.im - r.im).abs() < 1e-6,
                "n={n} bin {i}: {p:?} vs {r:?}"
            );
        }
        plan.inverse(&mut planned, &mut scratch);
        for (p, orig) in planned.iter().zip(&x) {
            assert!((p.re - orig.re).abs() < 1e-6 && (p.im - orig.im).abs() < 1e-6, "n={n}");
        }
    });
}

/// Planned Bluestein (non-power-of-two) transforms match the naive DFT
/// oracle to 1e-6.
#[test]
fn prop_planned_fft_matches_naive_bluestein() {
    for_cases(20, |rng| {
        let mut n = 3 + rng.next_bounded(60) as usize;
        if n.is_power_of_two() {
            n += 1; // 4,8,16,32 → 5,9,17,33: all non-pow2
        }
        let x: Vec<fft::Complex> = (0..n)
            .map(|_| fft::Complex::new(rng.gaussian() as f64, rng.gaussian() as f64))
            .collect();
        let plan = fft::FftPlan::new(n);
        let mut scratch = plan.make_scratch();
        let mut planned = x.clone();
        plan.forward(&mut planned, &mut scratch);
        let oracle = fft::dft_naive(&x);
        for (i, (p, r)) in planned.iter().zip(&oracle).enumerate() {
            assert!(
                (p.re - r.re).abs() < 1e-6 && (p.im - r.im).abs() < 1e-6,
                "n={n} bin {i}: {p:?} vs {r:?}"
            );
        }
    });
}

/// Planned rfft/irfft match the (plan-cached) free functions to 1e-6 and
/// round-trip the signal, for power-of-two and Bluestein lengths alike.
#[test]
fn prop_planned_rfft_matches_free_functions() {
    for_cases(30, |rng| {
        let n = 2 + rng.next_bounded(80) as usize;
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        let plan = fft::RfftPlan::new(n);
        let mut scratch = plan.make_scratch();
        let mut spec = vec![fft::Complex::ZERO; plan.bins()];
        plan.forward_into(&x, &mut spec, &mut scratch);
        let free = fft::rfft(&x);
        for (i, (p, r)) in spec.iter().zip(&free).enumerate() {
            assert!(
                (p.re - r.re).abs() < 1e-6 && (p.im - r.im).abs() < 1e-6,
                "n={n} bin {i}"
            );
        }
        let mut back = vec![0.0f32; n];
        plan.inverse_into(&spec, &mut back, &mut scratch);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4, "n={n}: {a} vs {b}");
        }
    });
}

/// Every rfft route — default (split-radix at pow2), forced-generic,
/// forced-Bluestein, and both explicit butterfly flavors — matches the
/// naive O(d²) real-DFT oracle, across power-of-two and arbitrary
/// lengths alike. Routes that require a power of two are only built
/// where they are valid.
#[test]
fn prop_rfft_routes_match_naive_oracle() {
    for_cases(30, |rng| {
        // Alternate pow2 (2..=256) and arbitrary (2..=96) lengths.
        let n = if rng.next_bounded(2) == 0 {
            1usize << (1 + rng.next_bounded(8))
        } else {
            2 + rng.next_bounded(95) as usize
        };
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        // Oracle: complex-embed the real signal, naive DFT, keep the
        // first n/2+1 bins.
        let embedded: Vec<fft::Complex> = x
            .iter()
            .map(|&v| fft::Complex::new(v as f64, 0.0))
            .collect();
        let oracle = fft::dft_naive(&embedded);
        let mut routes = vec![
            ("default", fft::RfftPlan::new(n)),
            ("generic", fft::RfftPlan::generic(n)),
            ("bluestein", fft::RfftPlan::bluestein(n)),
        ];
        if n.is_power_of_two() {
            routes.push(("scalar", fft::RfftPlan::with_exec(n, fft::FftExec::Scalar)));
            routes.push(("simd", fft::RfftPlan::with_exec(n, fft::FftExec::Simd)));
        }
        for (name, plan) in &routes {
            let mut scratch = plan.make_scratch();
            let mut spec = vec![fft::Complex::ZERO; plan.bins()];
            plan.forward_into(&x, &mut spec, &mut scratch);
            let tol = 1e-6 * (1.0 + n as f64);
            for (i, (p, r)) in spec.iter().zip(&oracle).enumerate() {
                assert!(
                    (p.re - r.re).abs() < tol && (p.im - r.im).abs() < tol,
                    "route {name} n={n} bin {i}: {p:?} vs {r:?}"
                );
            }
        }
    });
}

/// The SIMD butterfly flavor is bit-for-bit identical to the scalar one
/// on forward and inverse transforms at random power-of-two lengths:
/// both flavors run the same IEEE operations in the same order (the lane
/// path only groups independent butterflies), so this is exact `to_bits`
/// equality, not a 1-ulp tolerance.
#[test]
fn prop_simd_flavor_is_bit_identical_to_scalar() {
    for_cases(30, |rng| {
        let n = 1usize << (1 + rng.next_bounded(10));
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        let sc = fft::RfftPlan::with_exec(n, fft::FftExec::Scalar);
        let sd = fft::RfftPlan::with_exec(n, fft::FftExec::Simd);
        let (mut ssc, mut ssd) = (sc.make_scratch(), sd.make_scratch());
        let mut spec_sc = vec![fft::Complex::ZERO; sc.bins()];
        let mut spec_sd = vec![fft::Complex::ZERO; sd.bins()];
        sc.forward_into(&x, &mut spec_sc, &mut ssc);
        sd.forward_into(&x, &mut spec_sd, &mut ssd);
        for (i, (a, b)) in spec_sc.iter().zip(&spec_sd).enumerate() {
            assert_eq!(a.re.to_bits(), b.re.to_bits(), "n={n} bin {i} re");
            assert_eq!(a.im.to_bits(), b.im.to_bits(), "n={n} bin {i} im");
        }
        let mut back_sc = vec![0.0f32; n];
        let mut back_sd = vec![0.0f32; n];
        sc.inverse_into(&spec_sc, &mut back_sc, &mut ssc);
        sd.inverse_into(&spec_sd, &mut back_sd, &mut ssd);
        for (i, (a, b)) in back_sc.iter().zip(&back_sd).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "n={n} sample {i}");
        }
    });
}

// --------------------------------------------------------------- kernels

/// The spectral and grouped kernels match the materialized-matrix oracle
/// (`sumvec_naive` / `r_sum_grouped_naive`) for q ∈ {L1, L2} and block
/// sizes b ∈ {1, 2, 4}.
#[test]
fn prop_kernels_match_naive_oracle() {
    for_cases(15, |rng| {
        let n = 2 + rng.next_bounded(8) as usize;
        let d = 4 + rng.next_bounded(16) as usize;
        let a = rand_tensor(rng, n, d);
        let b = rand_tensor(rng, n, d);
        let c = regularizer::cross_correlation(&a, &b, n as f32);
        let mut fk = FftSumvecKernel::new(d);
        fk.accumulate(&a, &b);
        let sv = fk.sumvec(n as f32);
        let sv_naive = regularizer::sumvec_naive(&c);
        for (i, (x, y)) in sv.iter().zip(&sv_naive).enumerate() {
            assert!(
                (x - y).abs() < 1e-3 * (1.0 + x.abs()),
                "n={n} d={d} i={i}: {x} vs {y}"
            );
        }
        for q in [Q::L1, Q::L2] {
            let fast = fk.r_sum(n as f32, q);
            let naive = regularizer::r_sum_from_sumvec(&sv_naive, q);
            assert!(
                (fast - naive).abs() < 1e-3 * (1.0 + naive.abs()),
                "q={q:?}: {fast} vs {naive}"
            );
            for block in [1usize, 2, 4] {
                let mut gk = GroupedFftKernel::new(d, block);
                gk.accumulate(&a, &b);
                let fast = gk.r_sum(n as f32, q);
                // padded oracle: d is random here, so blocks may be ragged
                // (the kernel zero-pads; the validated free fns reject).
                let naive = regularizer::r_sum_grouped_padded_naive(&c, block, q);
                assert!(
                    (fast - naive).abs() < 1e-3 * (1.0 + naive.abs()),
                    "block={block} q={q:?}: {fast} vs {naive}"
                );
            }
        }
    });
}

/// Multi-threaded sample-chunk accumulation matches sequential
/// accumulation for every kernel, at random shapes and thread counts.
#[test]
fn prop_threaded_accumulation_matches_sequential() {
    for_cases(10, |rng| {
        let n = 4 + rng.next_bounded(28) as usize;
        let d = 4 + rng.next_bounded(24) as usize;
        let threads = 2 + rng.next_bounded(5) as usize;
        let a = rand_tensor(rng, n, d);
        let b = rand_tensor(rng, n, d);
        let mut seq = FftSumvecKernel::new(d);
        let mut par = FftSumvecKernel::with_threads(d, threads);
        seq.accumulate(&a, &b);
        par.accumulate(&a, &b);
        for (x, y) in seq.sumvec(n as f32).iter().zip(&par.sumvec(n as f32)) {
            assert!((x - y).abs() < 1e-4 * (1.0 + x.abs()), "t={threads}: {x} vs {y}");
        }
        let mut nseq = NaiveMatrixKernel::new(d);
        let mut npar = NaiveMatrixKernel::with_threads(d, threads);
        nseq.accumulate(&a, &b);
        npar.accumulate(&a, &b);
        let (x, y) = (nseq.r_off(n as f32).unwrap(), npar.r_off(n as f32).unwrap());
        assert!((x - y).abs() < 1e-4 * (1.0 + x.abs()));
        let mut gseq = GroupedFftKernel::new(d, 4);
        let mut gpar = GroupedFftKernel::with_threads(d, 4, threads);
        gseq.accumulate(&a, &b);
        gpar.accumulate(&a, &b);
        let (x, y) = (gseq.r_sum(n as f32, Q::L2), gpar.r_sum(n as f32, Q::L2));
        assert!((x - y).abs() < 1e-4 * (1.0 + x.abs()));
    });
}

// ------------------------------------------------------------------ data

/// Batches are deterministic functions of (seed, index) and label-aligned
/// across views, at random batch sizes.
#[test]
fn prop_batches_deterministic_and_aligned() {
    for_cases(10, |rng| {
        let batch = 1 + rng.next_bounded(12) as usize;
        let seed = rng.next_u64();
        let bi = rng.next_bounded(100);
        let ds = ShapeWorld::new(ShapeWorldConfig {
            seed,
            ..Default::default()
        });
        let aug = Augmenter::new(AugmentConfig::default());
        let b1 = make_batch(&ds, &aug, batch, 1000, seed, bi);
        let b2 = make_batch(&ds, &aug, batch, 1000, seed, bi);
        assert_eq!(b1.view_a.images.data(), b2.view_a.images.data());
        assert_eq!(b1.view_a.labels, b1.view_b.labels);
        assert_eq!(b1.view_a.images.shape()[0], batch);
    });
}

/// Labels are always within the vocabulary range.
#[test]
fn prop_labels_in_range() {
    for_cases(10, |rng| {
        let vocab = if rng.bernoulli(0.5) { Vocab::A } else { Vocab::B };
        let ds = ShapeWorld::new(ShapeWorldConfig {
            seed: rng.next_u64(),
            vocab,
            ..Default::default()
        });
        for i in 0..50 {
            assert!((ds.sample(i).label as usize) < ds.num_classes());
        }
    });
}

// ------------------------------------------------------------ scheduling

/// LR is always positive, bounded by base, and continuous at the
/// warmup/cosine boundary.
#[test]
fn prop_lr_schedule_sane() {
    for_cases(30, |rng| {
        let spe = 1 + rng.next_bounded(50) as usize;
        let warm = rng.next_bounded(5) as usize;
        let epochs = 1 + warm + rng.next_bounded(20) as usize;
        let base = rng.uniform(0.01, 1.0);
        let s = LrSchedule::from_epochs(base, warm, epochs, spe);
        let total = epochs * spe;
        for step in 0..total {
            let lr = s.lr(step);
            assert!(lr > 0.0 && lr <= base * 1.0001, "step {step}: {lr}");
        }
        if warm > 0 {
            let boundary = warm * spe;
            let before = s.lr(boundary - 1);
            let after = s.lr(boundary);
            assert!((before - after).abs() < base * 0.25, "jump at warmup end");
        }
    });
}

// ------------------------------------------------------------------ json

/// JSON round-trips arbitrary nested values built from the RNG.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.next_bounded(4) } else { rng.next_bounded(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.bernoulli(0.5)),
            2 => json::Json::Num((rng.gaussian() * 100.0).round() as f64),
            3 => json::Json::Str(format!("s{}✓\"\\{}", rng.next_bounded(10), rng.next_bounded(10))),
            4 => json::Json::Arr((0..rng.next_bounded(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.next_bounded(4) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                json::Json::Obj(m)
            }
        }
    }
    for_cases(50, |rng| {
        let v = gen(rng, 3);
        let text = v.to_string_compact();
        let back = json::parse(&text).unwrap();
        assert_eq!(back, v, "{text}");
    });
}

// ---------------------------------------------------------------- config

/// Every variant round-trips through its artifact-name fragment, and the
/// train artifact name embeds both variant and preset.
#[test]
fn prop_config_artifact_names() {
    for v in Variant::all() {
        let mut cfg = TrainConfig::default();
        cfg.spec = v.spec();
        for preset in ["tiny", "small", "e2e"] {
            cfg.preset = preset.into();
            let name = cfg.train_artifact();
            assert!(name.contains(v.as_str()));
            assert!(name.ends_with(preset));
            // the legacy string and the spec-derived id agree exactly
            assert_eq!(name, format!("train_{}_{preset}", v.as_str()));
        }
    }
}

// ------------------------------------------------------------- loss spec

/// Draw a random spec from the full product space: family × form
/// (off / sum / grouped, q ∈ {1, 2}, assorted blocks) × norm × λ ×
/// threads.
fn rand_spec(rng: &mut Rng) -> LossSpec {
    let family = if rng.bernoulli(0.5) {
        LossFamily::BarlowTwins
    } else {
        LossFamily::VicReg
    };
    let q = if rng.bernoulli(0.5) {
        decorr::regularizer::Q::L1
    } else {
        decorr::regularizer::Q::L2
    };
    let form = match rng.next_bounded(3) {
        0 => RegularizerForm::OffDiag,
        1 => RegularizerForm::Sum { q },
        _ => {
            let blocks = [1usize, 2, 16, 64, 128, 256, 2048];
            RegularizerForm::GroupedSum {
                q,
                block: blocks[rng.next_bounded(blocks.len() as u64) as usize],
            }
        }
    };
    let mut b = LossSpec::builder(family).form(form);
    if rng.bernoulli(0.5) {
        b = b.norm(if rng.bernoulli(0.5) {
            NormConvention::BatchSize
        } else {
            NormConvention::Unbiased
        });
    }
    if rng.bernoulli(0.5) {
        let lambdas = [1.0f32, 0.005, 0.0051, 2.0f32.powi(-10), 25.0, 0.5];
        b = b.lambda(lambdas[rng.next_bounded(lambdas.len() as u64) as usize]);
    }
    if rng.bernoulli(0.5) {
        b = b.threads(rng.next_bounded(9) as usize); // 0 (auto) ..= 8
    }
    b.build().expect("non-zero blocks always build")
}

/// `LossSpec::parse(spec.to_string()) == spec` over the full product
/// space — the canonical-form round-trip the config layer depends on.
#[test]
fn prop_loss_spec_roundtrip() {
    for_cases(200, |rng| {
        let spec = rand_spec(rng);
        let text = spec.to_string();
        let back = LossSpec::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of '{text}' failed: {e}"));
        assert_eq!(back, spec, "{text}");
        // parsing is case-insensitive
        let upper = LossSpec::parse(&text.to_ascii_uppercase())
            .unwrap_or_else(|e| panic!("upper-case reparse of '{text}' failed: {e}"));
        assert_eq!(upper, spec, "{text}");
    });
}

/// The artifact fragment itself parses back to the same structural spec
/// (fragments do not carry norm/λ/threads, so compare the structure).
#[test]
fn prop_spec_fragment_parses_back() {
    for_cases(100, |rng| {
        let spec = rand_spec(rng);
        let frag = spec.artifact_fragment();
        let back = LossSpec::parse(&frag)
            .unwrap_or_else(|e| panic!("fragment '{frag}' failed: {e}"));
        assert_eq!(back.family, spec.family, "{frag}");
        assert_eq!(back.form, spec.form, "{frag}");
        assert_eq!(back.artifact_fragment(), frag);
    });
}

// ----------------------------------------------------------------- shards

/// Per-case temp shard path (pid + tag keeps parallel test runs apart).
fn shard_tmp(tag: u64) -> String {
    std::env::temp_dir()
        .join(format!("decorr_prop_shard_{}_{tag}.bin", std::process::id()))
        .to_str()
        .expect("utf-8 temp path")
        .to_string()
}

/// Shard pack → read round-trips every sample bit-identically, through
/// both the mmap and the pread read paths, across random shapes/counts.
#[test]
fn prop_shard_roundtrip_bit_identical() {
    for_cases(25, |rng| {
        let rank = 1 + rng.next_bounded(3) as usize;
        let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.next_bounded(6) as usize).collect();
        let count = 1 + rng.next_bounded(12) as usize;
        let stride: usize = shape.iter().product();
        let samples: Vec<Sample> = (0..count)
            .map(|i| Sample {
                image: Tensor::from_vec(&shape, (0..stride).map(|_| rng.gaussian()).collect()),
                label: i as u32 ^ 0xAB,
            })
            .collect();
        let path = shard_tmp(rng.next_bounded(1 << 40));
        let mut w = ShardWriter::create(&path, &shape).unwrap();
        for s in &samples {
            w.push(s).unwrap();
        }
        assert_eq!(w.finish().unwrap(), count as u64);
        let readers = [
            ShardReader::open(&path).unwrap(),
            ShardReader::open_pread(&path).unwrap(),
        ];
        for reader in &readers {
            assert_eq!(reader.count(), count as u64);
            assert_eq!(reader.shape(), &shape[..]);
            for (i, s) in samples.iter().enumerate() {
                let got = reader.read_sample(i as u64).unwrap();
                assert_eq!(got.label, s.label, "label {i}");
                let a: Vec<u32> = got.image.data().iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = s.image.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "sample {i} payload");
            }
        }
        drop(readers);
        std::fs::remove_file(&path).ok();
    });
}

/// Any single corruption of a valid shard — truncation, bad magic, an
/// unknown version, trailing garbage — is rejected at open, never served
/// as a mangled read.
#[test]
fn prop_shard_rejects_corruption() {
    for_cases(25, |rng| {
        let path = shard_tmp(0xC0_0000_0000 | rng.next_bounded(1 << 40));
        let shape = [2usize, 3];
        let mut w = ShardWriter::create(&path, &shape).unwrap();
        for i in 0..4u32 {
            w.push(&Sample {
                image: Tensor::from_vec(&shape, (0..6).map(|_| rng.gaussian()).collect()),
                label: i,
            })
            .unwrap();
        }
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut corrupt = bytes.clone();
        match rng.next_bounded(4) {
            0 => {
                let cut = 1 + rng.next_bounded(bytes.len() as u64 / 2) as usize;
                corrupt.truncate(bytes.len() - cut);
            }
            1 => corrupt[0] ^= 0xFF,                   // magic
            2 => corrupt[8] = 0x7F,                    // version
            _ => corrupt.extend_from_slice(&[0u8; 3]), // trailing bytes
        }
        std::fs::write(&path, &corrupt).unwrap();
        assert!(ShardReader::open(&path).is_err(), "corruption accepted");
        assert!(
            ShardReader::open_pread(&path).is_err(),
            "corruption accepted on the pread path"
        );
        std::fs::remove_file(&path).ok();
    });
}

// ---------------------------------------------------------- serving wire

/// A random request over the wire format's full envelope: both kinds,
/// arbitrary spec strings (the wire layer only caps length and requires
/// utf8 — spec *grammar* is validated later, server-side), small random
/// shapes, and payloads that occasionally contain non-finite floats.
fn rand_wire_request(rng: &mut Rng) -> Request {
    let rows = 1 + rng.next_bounded(6) as usize;
    let d = 1 + rng.next_bounded(24) as usize;
    let specs = ["bt_sum", "vic_off@t=4", "", "not a spec!", "日本語✓", "zz"];
    let elems = rows * d;
    let payload = |rng: &mut Rng| -> Vec<f32> {
        (0..elems)
            .map(|_| match rng.next_bounded(12) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                _ => rng.gaussian(),
            })
            .collect()
    };
    Request {
        id: rng.next_u64(),
        kind: if rng.bernoulli(0.5) {
            RequestKind::Score
        } else {
            RequestKind::Diagnose
        },
        spec: specs[rng.next_bounded(specs.len() as u64) as usize].to_string(),
        rows,
        d,
        a: payload(rng),
        b: payload(rng),
    }
}

/// Requests round-trip the wire bit-identically — ids, kinds, arbitrary
/// spec strings, and every payload f32 (including NaN/Inf bit patterns).
#[test]
fn prop_serve_request_roundtrip() {
    for_cases(60, |rng| {
        let req = rand_wire_request(rng);
        let frame = encode_request(&req);
        assert_eq!(&frame[..4], &REQ_MAGIC);
        let len = u32::from_le_bytes(frame[4..8].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 8);
        let back = decode_request_body(&frame[8..]).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.kind, req.kind);
        assert_eq!(back.spec, req.spec);
        assert_eq!(back.rows, req.rows);
        assert_eq!(back.d, req.d);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.a), bits(&req.a));
        assert_eq!(bits(&back.b), bits(&req.b));
    });
}

/// All three response variants round-trip the wire exactly.
#[test]
fn prop_serve_response_roundtrip() {
    for_cases(60, |rng| {
        let id = rng.next_u64();
        let resp = match rng.next_bounded(3) {
            0 => Response::Score {
                id,
                scores: (0..rng.next_bounded(8))
                    .map(|_| RowScore {
                        score: rng.gaussian() as f64,
                        align: rng.gaussian() as f64,
                    })
                    .collect(),
            },
            1 => Response::Diagnose {
                id,
                backend: if rng.bernoulli(0.5) {
                    RespondedBy::Host
                } else {
                    RespondedBy::Device
                },
                total: rng.gaussian() as f64,
                invariance: rng.bernoulli(0.5).then(|| rng.gaussian() as f64),
                regularizer: rng.bernoulli(0.5).then(|| rng.gaussian() as f64),
            },
            _ => Response::Error {
                id,
                code: rng.next_bounded(12) as u16,
                message: format!("err ✓ {}", rng.next_bounded(100)),
            },
        };
        let frame = encode_response(&resp);
        let back = decode_response_body(&frame[8..]).unwrap();
        assert_eq!(back, resp);
    });
}

/// Any prefix truncation of a valid frame decodes to a typed framing
/// error (`Closed` before any byte, `Truncated` after) — never a panic,
/// never a mangled `Ok`.
#[test]
fn prop_serve_truncated_frames_reject() {
    for_cases(50, |rng| {
        let req = rand_wire_request(rng);
        let frame = encode_request(&req);
        let cut = rng.next_bounded(frame.len() as u64) as usize; // 0..len-1: always short
        let mut r: &[u8] = &frame[..cut];
        let err = read_frame(&mut r, REQ_MAGIC, MAX_FRAME)
            .expect_err("truncated frame must not decode");
        match (cut, &err) {
            (0, ServeError::Closed) => {}
            (_, ServeError::Truncated { .. }) => {}
            other => panic!("cut={cut}: unexpected {:?}", other.1),
        }
        assert!(err.is_framing(), "truncation must close the connection");
        // Body-level truncation is typed too: every short body errors.
        if cut > 8 {
            let err = decode_request_body(&frame[8..cut])
                .expect_err("short body must not decode");
            assert!(err.code() > 0);
        }
    });
}

/// Corrupt headers are rejected before any allocation: wrong magic →
/// `BadMagic` echoing the bytes, oversize length prefix → `Oversize`,
/// wrong version byte → `BadVersion`.
#[test]
fn prop_serve_bad_headers_reject() {
    for_cases(50, |rng| {
        let frame = encode_request(&rand_wire_request(rng));
        // Flip one magic byte.
        let mut bad = frame.clone();
        let i = rng.next_bounded(4) as usize;
        bad[i] ^= 1 + rng.next_bounded(255) as u8;
        let mut r: &[u8] = &bad;
        match read_frame(&mut r, REQ_MAGIC, MAX_FRAME) {
            Err(ServeError::BadMagic { got }) => assert_eq!(got, bad[..4]),
            other => panic!("bad magic accepted: {other:?}"),
        }
        // Oversize length prefix: rejected by header inspection alone,
        // even though no such body exists to read.
        let mut bad = frame.clone();
        let lie = (MAX_FRAME as u32) + 1 + rng.next_bounded(1 << 20) as u32;
        bad[4..8].copy_from_slice(&lie.to_le_bytes());
        let mut r: &[u8] = &bad;
        match read_frame(&mut r, REQ_MAGIC, MAX_FRAME) {
            Err(ServeError::Oversize { len, max }) => {
                assert_eq!(len, lie as usize);
                assert_eq!(max, MAX_FRAME);
            }
            other => panic!("oversize accepted: {other:?}"),
        }
        // Unknown version byte (first body byte).
        let mut bad = frame;
        bad[8] = 2 + rng.next_bounded(254) as u8;
        match decode_request_body(&bad[8..]) {
            Err(ServeError::BadVersion(v)) => assert_eq!(v, bad[8]),
            other => panic!("bad version accepted: {other:?}"),
        }
    });
}

/// Arbitrary byte soup never panics either decoder — it decodes or it
/// returns a typed error (the `for_cases` harness converts any panic
/// into a failure with the reproducing seed).
#[test]
fn prop_serve_garbage_bodies_never_panic() {
    for_cases(80, |rng| {
        let len = rng.next_bounded(200) as usize;
        let body: Vec<u8> = (0..len).map(|_| rng.next_bounded(256) as u8).collect();
        let _ = decode_request_body(&body);
        let _ = decode_response_body(&body);
    });
}

/// Spec-grammar validation (the server-side layer above the wire) is
/// typed: garbage specs are `BadSpec` request errors the connection
/// survives, out-of-range rows are `RowsOutOfRange`, and well-formed
/// requests produce the queue key they route on.
#[test]
fn prop_serve_unknown_specs_typed_rejection() {
    for_cases(40, |rng| {
        let d = 2 + rng.next_bounded(30) as usize;
        let garbage = format!("zz{}!{}", rng.next_bounded(100), rng.next_bounded(100));
        match SpecExecCache::validate(RequestKind::Score, &garbage, 1, d, 64) {
            Err(e @ ServeError::BadSpec { .. }) => {
                assert!(!e.is_framing(), "spec errors must not close the connection")
            }
            other => panic!("garbage spec '{garbage}' accepted: {other:?}"),
        }
        let max = 1 + rng.next_bounded(64) as usize;
        let too_many = max + 1 + rng.next_bounded(64) as usize;
        match SpecExecCache::validate(RequestKind::Score, "bt_sum", too_many, d, max) {
            Err(ServeError::RowsOutOfRange { rows, max: m }) => {
                assert_eq!(rows, too_many);
                assert_eq!(m, max);
            }
            other => panic!("rows {too_many} > {max} accepted: {other:?}"),
        }
        let key = SpecExecCache::validate(RequestKind::Diagnose, "bt_sum", 1, d, 64).unwrap();
        assert_eq!(key.d, d);
    });
}
