//! Multi-process DDP integration: real `decorr rank` subprocesses
//! exchanging gradients with a leader over a Unix socket must be
//! bit-identical to the in-process thread-backed `DdpTrainer` at the
//! same seed (the `coordinator::ddp_net` contract).
//!
//! The protocol itself (framing, typed errors, f32 bit-exactness) is
//! pinned by unit tests inside `coordinator::ddp_net`; this file covers
//! the part that needs real processes: handshake against a live leader,
//! job/grads exchange across process boundaries, and clean shutdown.

use std::process::{Child, Command, Stdio};

use decorr::api::train::DriverBuilder;
use decorr::config::TrainConfig;
use decorr::coordinator::DdpTrainer;
use decorr::data::loader::make_batch;
use decorr::data::synth::{ShapeWorld, ShapeWorldConfig};
use decorr::data::{AugmentConfig, Augmenter};

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/grad_bt_sum_small_s2.manifest.json").exists()
}

fn small_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::preset_small();
    cfg.out_dir = String::new();
    cfg.epochs = 1;
    cfg.steps_per_epoch = 3;
    cfg
}

/// Spawn one `decorr rank` worker pointed at `addr`. Ranks retry the
/// connect while the leader is still binding, so spawning them before
/// the leader exists is the intended order.
fn spawn_rank(addr: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_decorr"))
        .args(["rank", "--addr", addr, "--artifacts", "artifacts"])
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawning decorr rank")
}

/// K = 2 rank subprocesses over a private Unix socket, stepped in
/// lockstep with a thread-backed `DdpTrainer` on identical batches:
/// every per-step loss/invariance/regularizer value and every final
/// parameter must agree to the bit.
#[test]
fn rank_processes_match_thread_ddp_bit_exactly() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    const SHARDS: usize = 2;
    let cfg = small_cfg();

    // Reference run: the historical in-process thread exchange.
    let mut threads = DdpTrainer::new(cfg.clone(), SHARDS).unwrap();

    // Socket run: ranks first (they retry-connect), then the leader
    // (whose construction blocks until both ranks pass the handshake).
    let sock = std::env::temp_dir().join(format!("decorr-ddp-net-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let addr = format!("unix:{}", sock.display());
    let mut ranks: Vec<Child> = (0..SHARDS).map(|_| spawn_rank(&addr)).collect();
    let mut net = DriverBuilder::new(cfg.clone())
        .ddp_net(SHARDS, addr.clone())
        .build_ddp()
        .unwrap();
    assert_eq!(net.batch_size(), threads.batch_size());

    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed: cfg.seed,
        ..Default::default()
    });
    let aug = Augmenter::new(AugmentConfig::default());
    for step in 0..3 {
        let batch = make_batch(&dataset, &aug, net.batch_size(), 2048, cfg.seed, step);
        let mt = threads.step(&batch, 0).unwrap();
        let mn = net.step(&batch, 0).unwrap();
        assert_eq!(
            mt.loss.to_bits(),
            mn.loss.to_bits(),
            "step {step}: thread loss {} vs net loss {}",
            mt.loss,
            mn.loss
        );
        assert_eq!(mt.inv.to_bits(), mn.inv.to_bits(), "step {step}: inv");
        assert_eq!(mt.reg.to_bits(), mn.reg.to_bits(), "step {step}: reg");
    }

    // Identical losses could still hide divergent gradients; identical
    // parameters after three updates cannot.
    let st = threads.snapshot().unwrap();
    let sn = net.snapshot().unwrap();
    assert_eq!(st.tensors.len(), sn.tensors.len());
    for ((n1, t1), (n2, t2)) in st.tensors.iter().zip(&sn.tensors) {
        assert_eq!(n1, n2);
        let diverged = t1
            .data()
            .iter()
            .zip(t2.data())
            .filter(|(a, b)| a.to_bits() != b.to_bits())
            .count();
        assert_eq!(diverged, 0, "{n1}: {diverged} parameter(s) differ bitwise");
    }

    // Dropping the leader sends SHUTDOWN; every rank must exit cleanly.
    drop(net);
    for (i, rank) in ranks.iter_mut().enumerate() {
        let status = rank.wait().expect("waiting on rank");
        assert!(status.success(), "rank {i} exited with {status}");
    }
    let _ = std::fs::remove_file(&sock);
}

/// A leader whose shard count has no matching grad artifact must fail
/// its own build without wedging: the error surfaces before any rank
/// traffic, and already-spawned ranks exit once the socket closes.
#[test]
fn missing_shard_artifact_fails_leader_cleanly() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let sock = std::env::temp_dir().join(format!("decorr-ddp-net-bad-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    let addr = format!("unix:{}", sock.display());
    // No artifact is emitted for 3 shards on the small preset, so the
    // leader's source resolution fails before it ever binds the socket.
    let err = DriverBuilder::new(small_cfg()).ddp_net(3, addr).build_ddp();
    assert!(err.is_err());
    assert!(!sock.exists(), "failed leader left its socket behind");
}
