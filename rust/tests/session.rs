//! Integration tests for the runtime `Session` subsystem: content-
//! addressed compile caching, alias dedup, concurrent source resolution,
//! warmup, and the persistent compile index.
//!
//! These need a working PJRT CPU client but **not** `make artifacts` —
//! every test generates its own synthetic HLO artifacts via
//! `bench_harness::workload::SynthArtifacts`. When no PJRT client can be
//! created (XLA extension absent), the PJRT-dependent tests skip, same as
//! the artifact-gated tests in `integration.rs`.

use std::sync::Arc;

use decorr::bench_harness::SynthArtifacts;
use decorr::runtime::{Session, SharedSession, SESSION_INDEX_FILE};
use decorr::util::json;

/// Open a session over `dir`, or skip the test when PJRT is unavailable.
fn open_or_skip(dir: &std::path::Path) -> Option<Session> {
    match Session::open(dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: no PJRT client ({e:#})");
            None
        }
    }
}

#[test]
fn same_name_loads_share_one_compiled_artifact() {
    let synth = SynthArtifacts::generate("same_name", &[(4, 16)]).unwrap();
    let Some(session) = open_or_skip(&synth.dir) else {
        return;
    };
    let name = &synth.names[0];
    let first = session.load(name).unwrap();
    let second = session.load(name).unwrap();
    assert!(
        Arc::ptr_eq(&first, &second),
        "same-name loads must share the Arc"
    );
    let stats = session.stats();
    assert_eq!(stats.loads, 2);
    assert_eq!(stats.compiles, 1, "second load must not recompile");
    assert_eq!(stats.hits, 1);
    assert!(stats.compile_ms > 0.0);
    // The executable really runs.
    let value = SynthArtifacts::smoke(&first).unwrap();
    assert!(value.is_finite());
}

#[test]
fn identical_content_under_different_name_is_a_hit() {
    let synth = SynthArtifacts::generate("alias", &[(4, 16)]).unwrap();
    let original = synth.names[0].clone();
    synth.alias(&original, "renamed_copy").unwrap();
    let Some(session) = open_or_skip(&synth.dir) else {
        return;
    };
    let a = session.load(&original).unwrap();
    let b = session.load("renamed_copy").unwrap();
    assert!(
        Arc::ptr_eq(&a, &b),
        "identical HLO + io-signature must share one executable"
    );
    let stats = session.stats();
    assert_eq!(stats.compiles, 1);
    assert_eq!(stats.hits, 1);
    // Distinct names are distinct sources, though.
    assert_eq!(stats.source_reads, 2);
}

#[test]
fn differing_manifest_signature_misses() {
    let synth = SynthArtifacts::generate("sig_miss", &[(4, 16)]).unwrap();
    // Byte-identical HLO text, but the manifest renames the output: only
    // the io-signature differs, so a miss here proves the signature
    // participates in the content key (the HLO hash alone would collide).
    synth
        .variant_manifest(&synth.names[0], "renamed_output", 4, 16, "out_v2")
        .unwrap();
    let Some(session) = open_or_skip(&synth.dir) else {
        return;
    };
    let a = session.load(&synth.names[0]).unwrap();
    let b = session.load("renamed_output").unwrap();
    assert!(!Arc::ptr_eq(&a, &b));
    assert_eq!(session.stats().compiles, 2);
    assert_eq!(session.stats().hits, 0);
}

#[test]
fn different_shapes_compile_separately() {
    let synth = SynthArtifacts::generate("shapes", &[(4, 16), (4, 32)]).unwrap();
    let Some(session) = open_or_skip(&synth.dir) else {
        return;
    };
    let a = session.load(&synth.names[0]).unwrap();
    let b = session.load(&synth.names[1]).unwrap();
    assert!(!Arc::ptr_eq(&a, &b));
    assert_eq!(session.stats().compiles, 2);
    assert_eq!(a.manifest().inputs[0].shape, vec![4, 16]);
    assert_eq!(b.manifest().inputs[0].shape, vec![4, 32]);
}

/// The concurrent warmup stress test: many threads hammer the shared
/// source cache for overlapping names (each file is read exactly once),
/// then warmup — twice, with aliases mixed in — compiles each distinct
/// shape exactly once. Compiled executables are thread-affine (PJRT
/// handles are not `Send`), so the concurrency lives in the shared core
/// and the compile-dedup guarantee is checked through the stats counters.
#[test]
fn concurrent_warmup_compiles_each_shape_exactly_once() {
    let synth =
        SynthArtifacts::generate("warmup", &[(4, 16), (4, 32), (4, 64)]).unwrap();
    for name in &synth.names {
        synth.alias(name, &format!("{name}_alias")).unwrap();
    }
    let shared = SharedSession::open(&synth.dir);

    // Stage 1: 8 threads × (3 names + 3 aliases), all racing the source
    // cache. Every file must be read exactly once process-wide.
    let mut all_names: Vec<String> = synth.names.clone();
    all_names.extend(synth.names.iter().map(|n| format!("{n}_alias")));
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let shared = shared.clone();
            let names = all_names.clone();
            scope.spawn(move || {
                for name in &names {
                    shared.source(name).unwrap();
                }
            });
        }
    });
    let stats = shared.stats();
    assert_eq!(stats.source_requests, 8 * 6);
    assert_eq!(stats.source_reads, 6, "each source read exactly once");

    // Stage 2: warmup through an execution arm (skip if no PJRT).
    let session = match shared.session() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping warmup stage: no PJRT client ({e:#})");
            return;
        }
    };
    let name_refs: Vec<&str> = all_names.iter().map(String::as_str).collect();
    let report = session.warmup(&name_refs).unwrap();
    assert_eq!(report.requested, 6);
    assert_eq!(report.distinct_shapes, 3);
    assert_eq!(report.compiled, 3, "one compile per distinct shape");
    assert_eq!(report.reused, 3, "aliases are hits");
    assert!(report.compile_ms > 0.0);

    // A second warmup is all hits.
    let again = session.warmup(&name_refs).unwrap();
    assert_eq!(again.compiled, 0);
    assert_eq!(again.reused, 6);
    assert_eq!(session.stats().compiles, 3, "still three compiles total");
}

/// Acceptance: a cached reload is >= 100x faster than the cold compile.
#[test]
fn cached_reload_is_two_orders_faster_than_cold() {
    let synth = SynthArtifacts::generate("speedup", &[(8, 64)]).unwrap();
    let Some(session) = open_or_skip(&synth.dir) else {
        return;
    };
    let name = &synth.names[0];
    let t0 = std::time::Instant::now();
    let artifact = session.load(name).unwrap();
    let cold = t0.elapsed();
    SynthArtifacts::smoke(&artifact).unwrap();

    // Median of repeated cached loads, robust to scheduler noise.
    let mut samples: Vec<f64> = (0..50)
        .map(|_| {
            let t = std::time::Instant::now();
            let again = session.load(name).unwrap();
            let dt = t.elapsed().as_secs_f64();
            assert!(Arc::ptr_eq(&artifact, &again));
            dt
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cached = samples[samples.len() / 2];
    let speedup = cold.as_secs_f64() / cached.max(1e-9);
    assert!(
        speedup >= 100.0,
        "cached reload only {speedup:.0}x faster than cold compile \
         (cold {:.3} ms, cached {:.3} us)",
        cold.as_secs_f64() * 1e3,
        cached * 1e6
    );
}

#[test]
fn persistent_index_records_compiles() {
    let synth = SynthArtifacts::generate("index", &[(4, 16), (4, 32)]).unwrap();
    let Some(session) = open_or_skip(&synth.dir) else {
        return;
    };
    for name in &synth.names {
        session.load(name).unwrap();
    }
    let index_path = synth.dir.join(SESSION_INDEX_FILE);
    let text = std::fs::read_to_string(&index_path).expect("index written");
    let doc = json::parse(&text).expect("index is valid json");
    let entries = match doc.get("entries") {
        Some(json::Json::Obj(m)) => m,
        other => panic!("index missing entries object: {other:?}"),
    };
    assert_eq!(entries.len(), 2, "one entry per compiled shape");
    for entry in entries.values() {
        assert!(entry.get("compile_ms").and_then(json::Json::as_f64).unwrap() > 0.0);
        assert_eq!(
            entry.get("compiles").and_then(json::Json::as_usize),
            Some(1)
        );
        assert!(entry.get("hlo_bytes").and_then(json::Json::as_usize).unwrap() > 0);
    }

    // A fresh shared core over the same dir picks the index up, and a
    // recompile in the new process-view bumps the per-shape counter.
    drop(session);
    let Some(session2) = open_or_skip(&synth.dir) else {
        return;
    };
    session2.load(&synth.names[0]).unwrap();
    let text = std::fs::read_to_string(&index_path).unwrap();
    let doc = json::parse(&text).unwrap();
    let entries = match doc.get("entries") {
        Some(json::Json::Obj(m)) => m,
        other => panic!("index missing entries object: {other:?}"),
    };
    assert!(entries
        .values()
        .any(|e| e.get("compiles").and_then(json::Json::as_usize) == Some(2)));
}
