//! Golden tests for the `api::train` driver surface.
//!
//! The redesign's core promise is that routing `Trainer::run` /
//! `DdpTrainer::run` through the shared `run_loop` changes *nothing*
//! numerically: the artifact-gated tests here pin bit-identical step
//! losses between a hand-rolled pre-redesign loop and the driver path,
//! plus the save → resume → loss-continuity contract of
//! `DriverBuilder::resume_from`. The host-only tests cover the
//! `LrSchedule` boundary cases the loop depends on and the sweep grammar.

use std::sync::Arc;

use decorr::api::train::{
    prepare_inputs, run_driver, BenchObserver, CheckpointObserver, DriverBuilder, MetricsObserver,
    SweepPlan, TrainDriver, TrainObserver, TrainReport,
};
use decorr::api::{LossExecutor, LossSpec};
use decorr::config::TrainConfig;
use decorr::coordinator::{Checkpoint, LrSchedule};
use decorr::data::loader::make_batch;
use decorr::data::synth::{ShapeWorld, ShapeWorldConfig};
use decorr::data::{AugmentConfig, Augmenter, BatchLoader, LoaderBuilder};

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/train_bt_sum_tiny.manifest.json").exists()
}

fn train_artifact_present(spec: &LossSpec, preset: &str) -> bool {
    std::path::Path::new(&format!(
        "artifacts/{}.manifest.json",
        spec.train_artifact(preset)
    ))
    .exists()
}

/// A deterministic tiny config: single loader worker so batch order is
/// strictly sequential (multi-worker loaders may deliver out of index
/// order), silent logging.
fn tiny_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::preset_tiny();
    cfg.epochs = 2;
    cfg.steps_per_epoch = 4;
    cfg.out_dir = String::new();
    cfg.loader_workers = 1;
    cfg.log_every = usize::MAX;
    cfg
}

/// The pre-redesign `Trainer::run` skeleton, written out longhand as the
/// golden oracle: same loader construction, same nested epoch/step loop,
/// stepping the driver directly. Hands the session back for the next
/// build.
fn direct_loop_losses(
    mut driver: Box<dyn TrainDriver>,
) -> (Vec<f32>, decorr::runtime::Session) {
    let cfg = driver.config().clone();
    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed: cfg.seed,
        ..Default::default()
    });
    let loader = BatchLoader::new(
        dataset,
        AugmentConfig::default(),
        driver.batch_size().unwrap(),
        cfg.epoch_size,
        cfg.seed,
        cfg.loader_workers,
        cfg.prefetch,
    );
    let mut losses = Vec::new();
    for epoch in 0..cfg.epochs {
        for _ in 0..cfg.steps_per_epoch {
            let batch = loader.next().expect("loader alive");
            losses.push(driver.step(&batch, epoch).unwrap().loss);
        }
    }
    (losses, driver.into_session())
}

/// Paper-preset specs produce bit-identical step losses through the
/// shared `run_loop` vs the pre-redesign direct loop.
#[test]
fn run_loop_matches_direct_loop_bit_identically() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut checked = 0;
    for spec in LossSpec::paper_presets() {
        if !train_artifact_present(&spec, "tiny") {
            eprintln!("skipping {spec}: no tiny train artifact");
            continue;
        }
        let mut cfg = tiny_cfg();
        cfg.spec = spec;

        // Golden: the hand-rolled pre-redesign loop.
        let direct = DriverBuilder::new(cfg.clone()).build().unwrap();
        let (losses_direct, session) = direct_loop_losses(direct);

        // Redesigned: Trainer::run → run_loop delegation, over the same
        // session (the compiled train executable is a cache hit).
        let mut trainer = DriverBuilder::new(cfg).session(session).build_trainer().unwrap();
        let report = trainer.run().unwrap();
        let losses_loop: Vec<f32> = trainer.metrics().history().iter().map(|m| m.loss).collect();

        assert_eq!(
            losses_direct, losses_loop,
            "step losses diverged for {spec}"
        );
        assert_eq!(report.steps, losses_loop.len());
        assert_eq!(report.spec, spec.to_string());
        checked += 1;
    }
    assert!(checked > 0, "no paper-preset tiny artifacts found");
}

/// Marshal-ahead delivery is numerically invisible: step losses are
/// bit-identical between inline stepping (`step` on raw loader batches,
/// adapt + literal marshaling on the driver thread) and the prepared fast
/// path (`step_prepared` on marshal-ahead batches from prefetch workers),
/// at loader worker counts 1, 3, and 8 — ordered delivery pins the batch
/// sequence regardless of worker interleaving.
#[test]
fn marshal_ahead_losses_match_inline_at_any_worker_count() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = tiny_cfg();
    let dataset = || {
        ShapeWorld::new(ShapeWorldConfig {
            seed: cfg.seed,
            ..Default::default()
        })
    };

    // Golden: inline stepping over the sequential single-worker loader.
    let mut driver = DriverBuilder::new(cfg.clone()).build_trainer().unwrap();
    let loader = BatchLoader::new(
        dataset(),
        AugmentConfig::default(),
        driver.batch_size().unwrap(),
        cfg.epoch_size,
        cfg.seed,
        1,
        cfg.prefetch,
    );
    let mut inline = Vec::new();
    for epoch in 0..cfg.epochs {
        for _ in 0..cfg.steps_per_epoch {
            let batch = loader.next().expect("loader alive");
            inline.push(driver.step(&batch, epoch).unwrap().loss);
        }
    }
    let mut session = Some(driver.into_session());

    for workers in [1usize, 3, 8] {
        let mut driver = DriverBuilder::new(cfg.clone())
            .session(session.take().unwrap())
            .build_trainer()
            .unwrap();
        let loader = LoaderBuilder::new(Arc::new(dataset()), driver.batch_size().unwrap())
            .epoch_size(cfg.epoch_size)
            .seed(cfg.seed)
            .workers(workers)
            .prefetch(cfg.prefetch)
            .ordered(true)
            .prepare(prepare_inputs(driver.input_adapter()))
            .build();
        let mut prepared = Vec::new();
        for epoch in 0..cfg.epochs {
            for _ in 0..cfg.steps_per_epoch {
                let pb = loader.next_prepared().expect("loader alive");
                assert!(pb.prepared.is_some(), "prepare fn must run in workers");
                prepared.push(driver.step_prepared(&pb, epoch).unwrap().loss);
            }
        }
        assert_eq!(inline, prepared, "losses diverged at {workers} workers");
        session = Some(driver.into_session());
    }
}

/// Observers compose on one run: metrics mirroring, periodic checkpoints,
/// and throughput capture all fire without forking the loop.
#[test]
fn observers_fire_during_run() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = tiny_cfg();
    let total = cfg.total_steps();
    let dir = std::env::temp_dir().join(format!("decorr_obs_{}", std::process::id()));
    let mut trainer = DriverBuilder::new(cfg).build_trainer().unwrap();
    let mut mirror = MetricsObserver::in_memory();
    let mut ckpts = CheckpointObserver::new(dir.to_str().unwrap(), 3);
    let mut bench = BenchObserver::new();
    let report = run_driver(
        &mut trainer,
        &mut [&mut mirror, &mut ckpts, &mut bench],
    )
    .unwrap();
    // Mirror saw every step, in order, identical to the driver's logger.
    assert_eq!(mirror.logger().len(), total);
    let mirrored: Vec<f32> = mirror.logger().history().iter().map(|m| m.loss).collect();
    let primary: Vec<f32> = trainer.metrics().history().iter().map(|m| m.loss).collect();
    assert_eq!(mirrored, primary);
    // Periodic saves every 3 steps + the final checkpoint.
    assert_eq!(ckpts.saved().len(), total / 3 + 1);
    for path in ckpts.saved() {
        assert!(Checkpoint::load(path).is_ok(), "unreadable {path}");
    }
    // Throughput capture rendered a table consistent with the report.
    assert!(bench.median_step_ms().unwrap() > 0.0);
    assert!(bench.table().is_some());
    assert!(report.steps_per_sec > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}

/// save → resume → loss continuity: a resumed driver restores the saved
/// parameters bit-identically and keeps training at the saved loss level.
#[test]
fn save_resume_restores_params_and_loss_level() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.epochs = 2;
    cfg.steps_per_epoch = 6;
    let mut trainer = DriverBuilder::new(cfg.clone()).build_trainer().unwrap();
    let report = trainer.run().unwrap();
    let snap = trainer.snapshot().unwrap();
    let dir = std::env::temp_dir().join(format!("decorr_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.ckpt");
    snap.save(&path).unwrap();

    let mut resumed = DriverBuilder::new(cfg.clone())
        .session(trainer.into_session())
        .resume_from(path.to_str().unwrap())
        .build_trainer()
        .unwrap();
    // Bit-identical parameter restoration.
    let restored = resumed.snapshot().unwrap();
    assert_eq!(restored.num_params(), snap.num_params());
    for (name, t) in &snap.tensors {
        assert_eq!(restored.get(name).unwrap().data(), t.data(), "{name}");
    }
    // Continuity: the next step's loss stays at the trained level, well
    // below a fresh run's initial loss (optimizer state restarts at
    // zero, so exact equality with an uninterrupted run is not claimed).
    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed: cfg.seed,
        ..Default::default()
    });
    let aug = Augmenter::new(AugmentConfig::default());
    let batch = make_batch(
        &dataset,
        &aug,
        resumed.batch_size().unwrap(),
        cfg.epoch_size,
        cfg.seed,
        0,
    );
    let m = resumed.step(&batch, 0).unwrap();
    assert!(m.loss.is_finite());
    assert!(
        m.loss <= report.initial_loss * 1.2,
        "resumed loss {} regressed far above the fresh initial loss {}",
        m.loss,
        report.initial_loss
    );
    // A missing resume checkpoint is a typed build failure, not a panic.
    assert!(DriverBuilder::new(cfg)
        .resume_from(dir.join("nope.ckpt").to_str().unwrap())
        .build_trainer()
        .is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// v2 checkpoints round-trip the full run state: a resume from
/// `snapshot_state` restores parameters AND optimizer state bit-wise and
/// continues the global step (so the LR schedule picks up where the
/// saved run stood, instead of re-warming up).
#[test]
fn v2_resume_restores_optimizer_state_and_schedule_position() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.epochs = 2;
    cfg.steps_per_epoch = 5;
    let mut trainer = DriverBuilder::new(cfg.clone()).build_trainer().unwrap();
    trainer.run().unwrap();
    let state = trainer.snapshot_state().unwrap();
    assert_eq!(state.step, cfg.total_steps());
    assert!(state.has_run_state());
    assert!(state.num_opt_params() > 0, "tiny preset has optimizer state");
    let dir = std::env::temp_dir().join(format!("decorr_resume_v2_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("state.ckpt");
    state.save(&path).unwrap();

    let mut resumed = DriverBuilder::new(cfg.clone())
        .session(trainer.into_session())
        .resume_from(path.to_str().unwrap())
        .build_trainer()
        .unwrap();
    // Bit-identical restoration of params AND optimizer state.
    let restored = resumed.snapshot_state().unwrap();
    for (name, t) in &state.tensors {
        assert_eq!(restored.get(name).unwrap().data(), t.data(), "{name}");
    }
    for (name, t) in &state.opt_tensors {
        assert_eq!(restored.get_opt(name).unwrap().data(), t.data(), "opt {name}");
    }
    // The global step continues: the next step is numbered total_steps,
    // and its LR matches the schedule at that position — not warmup.
    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed: cfg.seed,
        ..Default::default()
    });
    let aug = Augmenter::new(AugmentConfig::default());
    let batch = make_batch(
        &dataset,
        &aug,
        resumed.batch_size().unwrap(),
        cfg.epoch_size,
        cfg.seed,
        0,
    );
    let m = resumed.step(&batch, 0).unwrap();
    assert_eq!(m.step, cfg.total_steps(), "global step must continue");
    let sched = LrSchedule::from_epochs(cfg.lr, cfg.warmup_epochs, cfg.epochs, cfg.steps_per_epoch);
    assert!(
        (m.lr - sched.lr(cfg.total_steps())).abs() < 1e-7,
        "resumed LR {} should sit at the schedule position, got schedule {}",
        m.lr,
        sched.lr(cfg.total_steps())
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The builder surfaces spec/artifact disagreements as errors.
#[test]
fn builder_rejects_unresolvable_specs() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = tiny_cfg();
    // No train artifact was lowered for this off-grid block size.
    cfg.spec = LossSpec::parse("bt_sum@b=63").unwrap();
    assert!(DriverBuilder::new(cfg).build_trainer().is_err());
}

/// LrSchedule boundary cases the shared loop leans on.
#[test]
fn lr_schedule_warmup_cosine_boundaries() {
    // Warmup's last step reaches base exactly; the cosine picks up from
    // base and decays monotonically to the floor.
    let s = LrSchedule::from_epochs(1.0, 1, 10, 10);
    assert!((s.lr(9) - 1.0).abs() < 1e-6, "warmup end: {}", s.lr(9));
    assert!(s.lr(10) <= 1.0 + 1e-6 && s.lr(10) > 0.9, "handoff: {}", s.lr(10));
    let mut prev = s.lr(10);
    for step in 11..100 {
        let cur = s.lr(step);
        assert!(cur <= prev + 1e-6, "step {step}: {cur} > {prev}");
        prev = cur;
    }
    assert!(s.lr(99) < 0.01);
    // Degenerate: warmup spans the whole run — cosine never engages
    // below base, and the post-run clamp holds.
    let w = LrSchedule::from_epochs(0.5, 2, 2, 5);
    assert!((w.lr(9) - 0.5).abs() < 1e-6);
    assert!((w.lr(10) - 0.5).abs() < 1e-6, "t=0 cosine: {}", w.lr(10));
    assert!(w.lr(1000) <= w.lr(10) + 1e-6);
    // Zero-length schedule stays finite at base.
    let z = LrSchedule::from_epochs(0.25, 0, 0, 0);
    assert!(z.lr(0).is_finite());
    assert!((z.lr(0) - 0.25).abs() < 1e-6);
}

/// The sweep grammar expands to host executors without artifacts — the
/// path `decorr sweep --host` (the CI smoke trajectory) takes.
#[test]
fn sweep_plan_runs_through_host_executors() {
    let plan = SweepPlan::parse("bt_sum@b={64,128},q={1,2}").unwrap();
    assert_eq!(plan.len(), 4);
    let (n, d) = (16usize, 256usize);
    let a = decorr::util::tensor::Tensor::zeros(&[n, d]);
    for spec in plan.specs() {
        let mut exec = spec.host_executor(d).unwrap();
        let out = exec.evaluate(&a, &a).unwrap();
        assert!(out.total.is_finite(), "{spec}");
    }
    // Blocks that don't divide d fail typed at executor construction.
    let bad = SweepPlan::parse("bt_sum@b={63}").unwrap();
    assert!(bad.specs()[0].host_executor(d).is_err());
}

/// TrainReport's JSON serializer emits the BENCH table shape consumed by
/// the perf-trajectory tooling.
#[test]
fn train_report_serializes_to_bench_shape() {
    let dir = std::env::temp_dir().join(format!("decorr_report_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_spec_grid.json");
    let reports = vec![TrainReport {
        spec: "bt_sum_g64_q1".into(),
        initial_loss: 3.0,
        final_loss: 1.5,
        steps: 8,
        wall_seconds: 2.0,
        steps_per_sec: 4.0,
    }];
    TrainReport::write_json(path.to_str().unwrap(), "spec_grid", &reports).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"spec_grid\""));
    assert!(text.contains("bt_sum_g64_q1"));
    assert!(text.contains("\"columns\"") && text.contains("\"rows\""));
    std::fs::remove_dir_all(&dir).ok();
}

/// A boxed driver built with `.ddp(1)` runs the same loop: one-shard DDP
/// losses track the monolithic trainer's within tolerance (the DDP
/// equivalence itself is pinned in tests/ddp.rs; here we check the
/// polymorphic path end to end).
#[test]
fn boxed_ddp_driver_runs_through_run_loop() {
    if !std::path::Path::new("artifacts/grad_bt_sum_small_s1.manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = TrainConfig::preset_small();
    cfg.out_dir = String::new();
    cfg.epochs = 1;
    cfg.steps_per_epoch = 3;
    cfg.loader_workers = 1;
    cfg.log_every = usize::MAX;
    let mut driver = DriverBuilder::new(cfg).ddp(1).build().unwrap();
    let mut bench = BenchObserver::new();
    let observers: &mut [&mut dyn TrainObserver] = &mut [&mut bench];
    let report = run_driver(driver.as_mut(), observers).unwrap();
    assert_eq!(report.steps, 3);
    assert!(report.final_loss.is_finite());
    assert!(bench.median_step_ms().is_some());
    // The session hands off through the boxed trait object too.
    let _session = driver.into_session();
}
