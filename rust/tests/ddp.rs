//! Integration tests for the simulated-DDP coordinator (paper App. E.3).

use decorr::config::TrainConfig;
use decorr::coordinator::{DdpTrainer, Trainer};
use decorr::data::loader::make_batch;
use decorr::data::synth::{ShapeWorld, ShapeWorldConfig};
use decorr::data::{AugmentConfig, Augmenter};

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/grad_bt_sum_small_s1.manifest.json").exists()
}

fn small_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::preset_small();
    cfg.out_dir = String::new();
    cfg.epochs = 1;
    cfg.steps_per_epoch = 3;
    cfg
}

/// With one shard, a DDP step (grad artifact + apply artifact) must be
/// mathematically identical to the fused monolithic train step.
#[test]
fn one_shard_matches_monolithic_step() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = small_cfg();
    let mut mono = Trainer::new(cfg.clone()).unwrap();
    let mut ddp = DdpTrainer::new(cfg.clone(), 1).unwrap();
    assert_eq!(mono.batch_size().unwrap(), ddp.batch_size());

    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed: cfg.seed,
        ..Default::default()
    });
    let aug = Augmenter::new(AugmentConfig::default());
    for step in 0..3 {
        let batch = make_batch(&dataset, &aug, ddp.batch_size(), 2048, cfg.seed, step);
        let m1 = mono.step(&batch, 0).unwrap();
        let m2 = ddp.step(&batch, 0).unwrap();
        let rel = (m1.loss - m2.loss).abs() / m1.loss.abs().max(1e-6);
        assert!(
            rel < 1e-3,
            "step {step}: monolithic {} vs ddp {} (rel {rel:.2e})",
            m1.loss,
            m2.loss
        );
    }
    // Parameters must agree after the same updates.
    let s1 = mono.snapshot().unwrap();
    let s2 = ddp.snapshot().unwrap();
    for ((n1, t1), (n2, t2)) in s1.tensors.iter().zip(&s2.tensors) {
        assert_eq!(n1, n2);
        let max_rel = t1
            .data()
            .iter()
            .zip(t2.data())
            .map(|(a, b)| (a - b).abs() / a.abs().max(1e-3))
            .fold(0.0f32, f32::max);
        assert!(max_rel < 1e-2, "{n1}: max rel diff {max_rel}");
    }
}

/// Multi-shard training runs and descends; per-shard losses average into
/// a finite global loss (the paper's no-collective-ops property: each
/// shard's loss uses only local statistics).
#[test]
fn multi_shard_training_descends() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = small_cfg();
    cfg.epochs = 1;
    cfg.steps_per_epoch = 8;
    cfg.log_every = usize::MAX;
    let mut ddp = DdpTrainer::new(cfg, 4).unwrap();
    assert_eq!(ddp.shards(), 4);
    let report = ddp.run().unwrap();
    assert!(report.final_loss.is_finite());
    assert!(
        report.final_loss < report.initial_loss * 1.05,
        "{} -> {}",
        report.initial_loss,
        report.final_loss
    );
}

/// Shard counts that don't match an emitted artifact fail cleanly.
#[test]
fn missing_shard_artifact_is_an_error() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = small_cfg();
    assert!(DdpTrainer::new(cfg, 3).is_err());
}
