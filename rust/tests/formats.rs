//! Doc-drift guard for `docs/FORMATS.md`: every magic byte string,
//! version number, and size ceiling the document quotes must match the
//! constants in code, so the format book cannot silently rot as formats
//! evolve. Renaming or re-versioning a format means updating the doc in
//! the same change — which is the point.

use std::sync::OnceLock;

/// The format book's text (the test fails loudly if the file moved).
fn formats_md() -> &'static str {
    static DOC: OnceLock<String> = OnceLock::new();
    DOC.get_or_init(|| {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/FORMATS.md");
        std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("docs/FORMATS.md must exist next to rust/ ({e})"))
    })
}

/// Assert the doc quotes `magic` exactly as code defines it.
fn assert_documented(what: &str, magic: &str) {
    assert!(
        formats_md().contains(magic),
        "docs/FORMATS.md no longer mentions the {what} magic '{magic}' — \
         update the doc to match the code constant"
    );
}

#[test]
fn registry_magic_matches_doc() {
    let magic = std::str::from_utf8(&decorr::runtime::registry::MAGIC).unwrap();
    assert_eq!(magic, "DCRREG01");
    assert_documented("registry entry", magic);
    assert_documented("registry source codec", decorr::runtime::registry::CODEC_SOURCE);
    assert_documented("registry pjrt codec", decorr::runtime::registry::CODEC_PJRT);
    assert_documented("registry portable fingerprint", decorr::runtime::registry::FP_PORTABLE);
    assert_documented("registry env var", decorr::runtime::registry::REGISTRY_ENV);
    assert_documented("registry entry suffix", decorr::runtime::registry::ENTRY_SUFFIX);
}

#[test]
fn shard_magic_matches_doc() {
    let magic = std::str::from_utf8(&decorr::data::shard::MAGIC).unwrap();
    assert_eq!(magic, "DCRSHRD1");
    assert_documented("shard file", magic);
}

#[test]
fn serve_magics_match_doc() {
    let req = std::str::from_utf8(&decorr::serve::protocol::REQ_MAGIC).unwrap();
    let resp = std::str::from_utf8(&decorr::serve::protocol::RESP_MAGIC).unwrap();
    assert_eq!((req, resp), ("DCRQ", "DCRP"));
    assert_documented("serve request", req);
    assert_documented("serve response", resp);
    // The doc quotes the frame ceiling as a shift expression; keep the
    // number and the prose in sync.
    assert_eq!(decorr::serve::protocol::MAX_FRAME, 1 << 26);
    assert_documented("serve frame ceiling", "MAX_FRAME = 1 << 26");
}

#[test]
fn ddp_net_magic_matches_doc() {
    let magic = std::str::from_utf8(&decorr::coordinator::ddp_net::MAGIC).unwrap();
    assert_eq!(magic, "DCRD");
    assert_documented("ddp-net frame", magic);
    assert_eq!(decorr::coordinator::ddp_net::MAX_FRAME, 1 << 28);
    assert_documented("ddp-net frame ceiling", "MAX_FRAME = 1 << 28");
}

#[test]
fn checkpoint_magics_match_doc() {
    // checkpoint.rs keeps its magics private (they never cross an API
    // boundary); pin the literals here against both the doc and a real
    // save so a silent rename fails this test, not a user's resume.
    assert_documented("checkpoint v1", "DECORRCKPT1");
    assert_documented("checkpoint v2", "DECORRCKPT2");
    let dir = std::env::temp_dir().join(format!("decorr_fmt_doc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("probe.ckpt");
    let ckpt = decorr::coordinator::Checkpoint {
        tensors: vec![("w".to_string(), decorr::util::tensor::Tensor::zeros(&[2, 2]))],
        ..Default::default()
    };
    ckpt.save(&path).unwrap();
    // The payload after the header is raw tensor bytes; compare bytes, not
    // text, so a non-UTF-8 payload never trips the probe.
    let head = std::fs::read(&path).unwrap();
    assert!(
        head.starts_with(b"DECORRCKPT1") || head.starts_with(b"DECORRCKPT2"),
        "checkpoint writer no longer emits a documented magic"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_registry_matches_doc() {
    // The doc points at DEFAULT_BENCH_FILES as the single registry of
    // gated files rather than duplicating the list; pin that pointer and
    // the naming convention the registry promises.
    for file in decorr::bench_harness::diff::DEFAULT_BENCH_FILES {
        assert!(
            file.starts_with("BENCH_") && file.ends_with(".json"),
            "unexpected bench registry entry {file}"
        );
    }
    assert_documented("bench registry", "DEFAULT_BENCH_FILES");
    assert_documented("session index", decorr::runtime::session::SESSION_INDEX_FILE);
}
