//! Integration tests over the real AOT artifacts (requires `make artifacts`).
//!
//! These exercise the full L3↔L2↔L1 stack: the rust PJRT runtime executes
//! jax-lowered HLO containing the Pallas kernels, and the results are
//! validated against the pure-rust host implementations of the paper's
//! quantities.

use decorr::config::TrainConfig;
use decorr::coordinator::trainer::{literal_f32, literal_i32, scalar};
use decorr::coordinator::{linear_eval, InputAdapter, Trainer};
use decorr::data::synth::{ShapeWorld, ShapeWorldConfig};
use decorr::regularizer;
use decorr::runtime::Engine;
use decorr::util::rng::Rng;
use decorr::util::tensor::Tensor;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/train_bt_sum_tiny.manifest.json").exists()
}

fn rand_tensor(rng: &mut Rng, n: usize, d: usize) -> Tensor {
    Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.gaussian()).collect())
}

/// Device loss artifact vs the pure-rust host implementation of the same
/// equation — the strongest cross-layer correctness signal in the repo.
#[test]
fn device_bt_sum_loss_matches_host_reference() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let art = engine.load_artifact("loss_bt_sum_d256_n128").unwrap();
    let (n, d) = (128usize, 256usize);

    let mut rng = Rng::new(42);
    let za = rand_tensor(&mut rng, n, d);
    let zb = rand_tensor(&mut rng, n, d);
    let perm: Vec<u32> = (0..d as u32).collect();

    let inputs = [
        literal_f32(&za).unwrap(),
        literal_f32(&zb).unwrap(),
        literal_i32(&perm).unwrap(),
    ];
    let out = art.execute_literals(&inputs).unwrap();
    let device_loss = scalar(&out[0]).unwrap();

    // Host: scale * (inv + λ·R_sum) with the aot.py bt_sum hyperparameters.
    let host_loss =
        0.125 * regularizer::barlow_twins_sum_loss(&za, &zb, 2f32.powi(-10), regularizer::Q::L2);
    let rel = (device_loss as f64 - host_loss).abs() / host_loss.abs().max(1e-9);
    assert!(
        rel < 2e-3,
        "device {device_loss} vs host {host_loss} (rel {rel:.2e})"
    );
}

/// Same check for the baseline R_off loss (crosscorr + offdiag kernels).
#[test]
fn device_bt_off_loss_matches_host_reference() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let art = engine.load_artifact("loss_bt_off_d256_n128").unwrap();
    let (n, d) = (128usize, 256usize);
    let mut rng = Rng::new(7);
    let za = rand_tensor(&mut rng, n, d);
    let zb = rand_tensor(&mut rng, n, d);
    let perm: Vec<u32> = (0..d as u32).collect();
    let inputs = [
        literal_f32(&za).unwrap(),
        literal_f32(&zb).unwrap(),
        literal_i32(&perm).unwrap(),
    ];
    let out = art.execute_literals(&inputs).unwrap();
    let device_loss = scalar(&out[0]).unwrap();
    let host_loss = 0.1 * regularizer::barlow_twins_loss(&za, &zb, 0.0051);
    let rel = (device_loss as f64 - host_loss).abs() / host_loss.abs().max(1e-9);
    assert!(
        rel < 2e-3,
        "device {device_loss} vs host {host_loss} (rel {rel:.2e})"
    );
}

/// Permutation invariance contract (§4.3): R_off path is permutation-
/// invariant on-device; the R_sum path is not.
#[test]
fn device_permutation_semantics() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu("artifacts").unwrap();
    let (n, d) = (128usize, 256usize);
    let mut rng = Rng::new(3);
    let za = rand_tensor(&mut rng, n, d);
    let zb = rand_tensor(&mut rng, n, d);
    let id: Vec<u32> = (0..d as u32).collect();
    let shuffled = rng.permutation(d);

    let run = |name: &str, perm: &[u32]| -> f32 {
        let art = engine.load_artifact(name).unwrap();
        let inputs = [
            literal_f32(&za).unwrap(),
            literal_f32(&zb).unwrap(),
            literal_i32(perm).unwrap(),
        ];
        scalar(&art.execute_literals(&inputs).unwrap()[0]).unwrap()
    };

    let off_id = run("loss_bt_off_d256_n128", &id);
    let off_pm = run("loss_bt_off_d256_n128", &shuffled);
    assert!(
        (off_id - off_pm).abs() / off_id.abs().max(1e-6) < 1e-3,
        "R_off must be permutation-invariant: {off_id} vs {off_pm}"
    );

    let sum_id = run("loss_bt_sum_d256_n128", &id);
    let sum_pm = run("loss_bt_sum_d256_n128", &shuffled);
    assert!(
        (sum_id - sum_pm).abs() > 1e-7,
        "R_sum should depend on the permutation: {sum_id} vs {sum_pm}"
    );
}

/// Trainer end-to-end on the tiny preset: losses finite + decreasing.
#[test]
fn tiny_training_run_descends() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = TrainConfig::preset_tiny();
    cfg.epochs = 2;
    cfg.steps_per_epoch = 15;
    cfg.out_dir = String::new(); // in-memory metrics
    cfg.lr = 0.1;
    let mut trainer = Trainer::new(cfg).unwrap();
    let report = trainer.run().unwrap();
    assert_eq!(report.steps, 30);
    assert!(report.final_loss.is_finite());
    assert!(
        report.final_loss < report.initial_loss,
        "no descent: {} -> {}",
        report.initial_loss,
        report.final_loss
    );
}

/// Snapshot → linear eval path: a briefly-trained tiny model must beat
/// chance on ShapeWorld classification.
#[test]
fn tiny_linear_eval_beats_chance() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = TrainConfig::preset_tiny();
    cfg.epochs = 2;
    cfg.steps_per_epoch = 15;
    cfg.out_dir = String::new();
    let seed = cfg.seed;
    let mut trainer = Trainer::new(cfg).unwrap();
    trainer.run().unwrap();
    let snapshot = trainer.snapshot().unwrap();
    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed,
        ..Default::default()
    });
    let result = linear_eval(
        trainer.session(),
        "tiny",
        &snapshot,
        &dataset,
        trainer.input_adapter(),
        512,
        256,
        120,
    )
    .unwrap();
    let chance = 1.0 / dataset.num_classes() as f32;
    assert!(
        result.top1 > chance + 0.1,
        "top1 {} should beat chance {}",
        result.top1,
        chance
    );
}

/// Checkpoint save/load through the trainer snapshot.
#[test]
fn snapshot_roundtrip() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = TrainConfig::preset_tiny();
    cfg.epochs = 1;
    cfg.steps_per_epoch = 2;
    cfg.out_dir = String::new();
    let mut trainer = Trainer::new(cfg).unwrap();
    let dataset = ShapeWorld::new(ShapeWorldConfig::default());
    let aug = decorr::data::Augmenter::new(decorr::data::AugmentConfig::default());
    let batch = decorr::data::loader::make_batch(
        &dataset,
        &aug,
        trainer.batch_size().unwrap(),
        256,
        1,
        0,
    );
    trainer.step(&batch, 0).unwrap();
    let snap = trainer.snapshot().unwrap();
    let dir = std::env::temp_dir().join(format!("decorr_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("snap.ckpt");
    snap.save(&path).unwrap();
    let back = decorr::coordinator::Checkpoint::load(&path).unwrap();
    assert_eq!(back.num_params(), snap.num_params());
    for (name, t) in &snap.tensors {
        assert_eq!(back.get(name).unwrap().data(), t.data(), "{name}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The InputAdapter must match the tiny artifact's flat input.
#[test]
fn tiny_adapter_is_flat() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = TrainConfig::preset_tiny();
    let trainer = Trainer::new(cfg).unwrap();
    assert_eq!(trainer.input_adapter(), InputAdapter::FlatGray(64));
    assert_eq!(trainer.embed_dim(), 256);
}
