//! Integration tests for the `decorr audit` lint pass: fixture crates
//! with seeded violations, the escape/ratchet machinery, and — most
//! importantly — the live tree itself, which must stay audit-clean
//! against the committed `rust/audit.toml` baseline.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use decorr::audit::baseline::{compare, Baseline};
use decorr::audit::rules::Rule;
use decorr::audit::{run_audit, AuditConfig};

/// Build a throwaway fixture crate: `root/src/<rel>` files plus an
/// optional `benches/` dir. Returns the root.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(tag: &str) -> Self {
        let root = std::env::temp_dir().join(format!("decorr_audit_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("src")).expect("fixture mkdir");
        Fixture { root }
    }

    fn write(&self, rel: &str, text: &str) -> &Self {
        let path = self.root.join(rel);
        std::fs::create_dir_all(path.parent().expect("fixture path has a parent"))
            .expect("fixture mkdir");
        std::fs::write(path, text).expect("fixture write");
        self
    }

    fn audit(&self, baseline: Baseline) -> decorr::audit::AuditOutcome {
        run_audit(&AuditConfig {
            root: self.root.clone(),
            baseline,
            workflow: None,
        })
        .expect("audit runs")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.root);
    }
}

#[test]
fn seeded_violations_are_detected() {
    let fx = Fixture::new("seeded");
    fx.write(
        "src/lib.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n\
         \x20   unsafe { g() };\n\
         \x20   *m.lock().unwrap()\n\
         }\n",
    );
    let out = fx.audit(Baseline::default());
    assert!(out.failed(), "seeded fixture must fail the audit");
    let counts: BTreeMap<_, _> = out.counts.clone();
    assert_eq!(counts.get(&Rule::Unsafe), Some(&1), "{:?}", out.violations);
    assert_eq!(counts.get(&Rule::Lock), Some(&1), "{:?}", out.violations);
    // The bare lock().unwrap() also counts as an unwrap in library code.
    assert_eq!(counts.get(&Rule::Unwrap), Some(&1), "{:?}", out.violations);
    // Violations carry usable locations.
    let v = &out.violations[0];
    assert_eq!(v.file, "lib.rs");
    assert!(v.line >= 1);
}

#[test]
fn allow_escapes_and_safety_comments_are_honored() {
    let fx = Fixture::new("escapes");
    fx.write(
        "src/lib.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 {\n\
         \x20   // SAFETY: g has no preconditions in this fixture.\n\
         \x20   unsafe { g() };\n\
         \x20   // audit: allow(lock, fixture exercises the escape path)\n\
         \x20   // audit: allow(unwrap, fixture exercises the escape path)\n\
         \x20   *m.lock().unwrap()\n\
         }\n",
    );
    let out = fx.audit(Baseline::default());
    assert!(!out.failed(), "escaped fixture must pass: {:?}", out.violations);
    assert!(out.violations.is_empty());
}

#[test]
fn test_code_is_exempt() {
    let fx = Fixture::new("testexempt");
    fx.write(
        "src/lib.rs",
        "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let x: Option<u32> = None;\n        x.unwrap();\n    }\n}\n",
    );
    let out = fx.audit(Baseline::default());
    assert!(!out.failed(), "{:?}", out.violations);
}

#[test]
fn ratchet_allows_baseline_debt_and_fails_regressions() {
    let fx = Fixture::new("ratchet");
    fx.write(
        "src/lib.rs",
        "pub fn f(a: Option<u32>, b: Option<u32>) -> u32 {\n\
         \x20   a.unwrap() + b.unwrap()\n\
         }\n",
    );
    // Two unwraps on one line are two violations; baseline 2 passes…
    let ok = fx.audit(Baseline::parse("[ratchet]\nunwrap = 2\n").expect("parse"));
    assert!(!ok.failed(), "{:?}", ok.violations);
    // …baseline 1 is a regression and fails.
    let bad = fx.audit(Baseline::parse("[ratchet]\nunwrap = 1\n").expect("parse"));
    assert!(bad.failed());
    assert_eq!(bad.ratchet.regressions, vec![(Rule::Unwrap, 2, 1)]);
    // Dropping below baseline is an improvement notice, not a failure.
    let loose = fx.audit(Baseline::parse("[ratchet]\nunwrap = 5\n").expect("parse"));
    assert!(!loose.failed());
    assert_eq!(loose.ratchet.improvements, vec![(Rule::Unwrap, 2, 5)]);
}

#[test]
fn nondet_and_thread_rules_fire_on_the_right_modules() {
    let fx = Fixture::new("modules");
    fx.write("src/fft/plan.rs", "pub fn t() { let _ = std::time::Instant::now(); }\n")
        .write("src/widgets.rs", "pub fn s() { std::thread::spawn(|| {}); }\n")
        .write(
            "src/serve/server.rs",
            "pub fn s() { std::thread::spawn(|| {}); }\n",
        )
        .write("src/lib.rs", "pub mod widgets;\n");
    let out = fx.audit(Baseline::default());
    assert_eq!(out.counts.get(&Rule::Nondet), Some(&1), "{:?}", out.violations);
    // widgets.rs fires; serve/server.rs is approved.
    assert_eq!(out.counts.get(&Rule::Thread), Some(&1), "{:?}", out.violations);
    assert!(out.violations.iter().any(|v| v.file == "widgets.rs"));
    assert!(!out.violations.iter().any(|v| v.file == "serve/server.rs"));
}

#[test]
fn bench_drift_fires_when_a_bench_output_is_unregistered() {
    let fx = Fixture::new("drift");
    fx.write("src/lib.rs", "\n")
        .write(
            "src/bench_harness/diff.rs",
            "pub const DEFAULT_BENCH_FILES: &[&str] = &[\"BENCH_known.json\"];\n",
        )
        .write(
            "benches/bench_thing.rs",
            "fn main() { write(\"BENCH_known.json\"); write(\"BENCH_rogue.json\"); }\n",
        );
    let out = fx.audit(Baseline::default());
    assert_eq!(out.counts.get(&Rule::BenchDrift), Some(&1), "{:?}", out.violations);
    assert!(out.violations[0].message.contains("BENCH_rogue.json"));
}

/// The tree audits itself: the repo must stay clean against the
/// committed baseline. Every rule except `unwrap` is at zero; `unwrap`
/// may only ratchet down.
#[test]
fn live_tree_is_audit_clean_against_committed_baseline() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let baseline_path = root.join("audit.toml");
    assert!(
        baseline_path.is_file(),
        "rust/audit.toml must be committed (regenerate with `decorr audit --write-baseline`)"
    );
    let baseline = Baseline::load(&baseline_path).expect("baseline parses");
    let workflow = root.join("../.github/workflows/ci.yml");
    let out = run_audit(&AuditConfig {
        root: root.clone(),
        baseline: baseline.clone(),
        workflow: workflow.is_file().then_some(workflow),
    })
    .expect("audit runs on the live tree");

    let zero_rules = [
        Rule::Unsafe,
        Rule::Lock,
        Rule::Nondet,
        Rule::Thread,
        Rule::BenchDrift,
    ];
    for rule in zero_rules {
        let offenders: Vec<String> = out
            .violations
            .iter()
            .filter(|v| v.rule == rule)
            .map(|v| v.to_string())
            .collect();
        assert!(
            offenders.is_empty(),
            "live tree has {rule} violations:\n{}",
            offenders.join("\n")
        );
    }
    let unwraps = out.counts.get(&Rule::Unwrap).copied().unwrap_or(0);
    assert!(
        unwraps <= baseline.allowed(Rule::Unwrap),
        "unwrap debt grew: {unwraps} > baseline {} — return errors or add a reasoned \
         `// audit: allow(unwrap, …)` escape",
        baseline.allowed(Rule::Unwrap)
    );
    assert!(!out.failed());
}

/// The ratchet comparison is pure — exercise it against the live counts
/// to pin the "counts only go down" contract end to end.
#[test]
fn live_tree_ratchet_would_catch_a_one_unwrap_regression() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let baseline = Baseline::load(&root.join("audit.toml")).expect("baseline parses");
    let out = run_audit(&AuditConfig {
        root,
        baseline: baseline.clone(),
        workflow: None,
    })
    .expect("audit runs");
    let mut inflated = out.counts.clone();
    *inflated.entry(Rule::Unwrap).or_insert(0) += 1;
    let report = compare(&inflated, &baseline);
    assert!(
        report.failed(),
        "one extra unwrap past the baseline must fail the ratchet"
    );
}

/// `audit.toml` must not list rules that are already at zero — the file
/// is a debt ledger, and paid-off rules leave it.
#[test]
fn committed_baseline_lists_only_live_debt() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let baseline = Baseline::load(&root.join("audit.toml")).expect("baseline parses");
    for rule in [Rule::Unsafe, Rule::Lock, Rule::Nondet, Rule::Thread, Rule::BenchDrift] {
        assert_eq!(
            baseline.allowed(rule),
            0,
            "{rule} must stay at zero — it is not ratcheted debt"
        );
    }
}
