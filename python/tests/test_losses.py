"""L2 loss semantics: equivalences the paper states, and permutation math.

Key identities under test:
* proposed loss with block=1, q=2  ==  original R_off loss (paper §4.4);
* block=d == no grouping;
* Pallas path == pure-jnp path for every variant;
* permutation leaves R_off and the invariance term unchanged but
  reshuffles sumvec (the §4.3 mechanism);
* gradients are finite and nonzero through every variant.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _views(seed, n, d):
    rng = np.random.RandomState(seed)
    return (
        jnp.asarray(rng.randn(n, d).astype(np.float32)),
        jnp.asarray(rng.randn(n, d).astype(np.float32)),
    )


def _identity_perm(d):
    return jnp.arange(d, dtype=jnp.int32)


class TestBTFamily:
    def test_block1_q2_equals_bt_off(self):
        za, zb = _views(0, 16, 12)
        perm = _identity_perm(12)
        off = M.LossConfig(variant="bt_off", lam=0.01, scale=1.0, use_pallas=False)
        grouped = M.LossConfig(
            variant="bt_sum", block=1, q=2, lam=0.01, scale=1.0, use_pallas=False
        )
        l_off, _ = M.loss_fn(za, zb, perm, off)
        l_g, _ = M.loss_fn(za, zb, perm, grouped)
        assert_allclose(float(l_off), float(l_g), rtol=1e-4)

    def test_block_d_equals_no_grouping(self):
        za, zb = _views(1, 8, 16)
        perm = _identity_perm(16)
        flat = M.LossConfig(variant="bt_sum", block=0, q=2, scale=1.0, use_pallas=False)
        grouped = M.LossConfig(variant="bt_sum", block=16, q=2, scale=1.0, use_pallas=False)
        lf, _ = M.loss_fn(za, zb, perm, flat)
        lg, _ = M.loss_fn(za, zb, perm, grouped)
        assert_allclose(float(lf), float(lg), rtol=1e-4)

    @pytest.mark.parametrize("variant,block", [
        ("bt_off", 0), ("bt_sum", 0), ("bt_sum", 4),
    ])
    def test_pallas_equals_jnp(self, variant, block):
        za, zb = _views(2, 8, 16)
        perm = _identity_perm(16)
        base = dict(variant=variant, block=block, q=2, scale=1.0)
        lp, _ = M.loss_fn(za, zb, perm, M.LossConfig(**base, use_pallas=True))
        lj, _ = M.loss_fn(za, zb, perm, M.LossConfig(**base, use_pallas=False))
        assert_allclose(float(lp), float(lj), rtol=1e-4)

    def test_invariance_term_is_permutation_invariant(self):
        za, zb = _views(3, 32, 8)
        rng = np.random.RandomState(0)
        perm = jnp.asarray(rng.permutation(8).astype(np.int32))
        cfg = M.LossConfig(variant="bt_sum", lam=0.0, scale=1.0, use_pallas=False)
        l_id, m_id = M.loss_fn(za, zb, _identity_perm(8), cfg)
        l_p, m_p = M.loss_fn(za, zb, perm, cfg)
        # λ=0: loss is pure invariance, which sums over features.
        assert_allclose(float(l_id), float(l_p), rtol=1e-4)
        assert_allclose(float(m_id["inv"]), float(m_p["inv"]), rtol=1e-4)

    def test_permutation_changes_regularizer(self):
        za, zb = _views(4, 16, 32)
        rng = np.random.RandomState(1)
        perm = jnp.asarray(rng.permutation(32).astype(np.int32))
        cfg = M.LossConfig(variant="bt_sum", scale=1.0, use_pallas=False)
        _, m_id = M.loss_fn(za, zb, _identity_perm(32), cfg)
        _, m_p = M.loss_fn(za, zb, perm, cfg)
        assert abs(float(m_id["reg"]) - float(m_p["reg"])) > 1e-6

    def test_r_off_is_permutation_invariant(self):
        za, zb = _views(5, 16, 12)
        rng = np.random.RandomState(2)
        perm = jnp.asarray(rng.permutation(12).astype(np.int32))
        cfg = M.LossConfig(variant="bt_off", lam=1.0, scale=1.0, use_pallas=False)
        _, m_id = M.loss_fn(za, zb, _identity_perm(12), cfg)
        _, m_p = M.loss_fn(za, zb, perm, cfg)
        assert_allclose(float(m_id["reg"]), float(m_p["reg"]), rtol=1e-4)

    def test_decorrelated_identical_views_minimize_loss(self):
        # For za == zb with independent features and n >> d, both the
        # invariance and regularizer terms should be near zero.
        rng = np.random.RandomState(3)
        z = jnp.asarray(rng.randn(2048, 4).astype(np.float32))
        cfg = M.LossConfig(variant="bt_sum", scale=1.0, lam=1.0, use_pallas=False)
        loss, m = M.loss_fn(z, z, _identity_perm(4), cfg)
        assert float(m["inv"]) < 1e-4
        assert float(m["reg"]) < 0.05


class TestVICFamily:
    def test_block1_q2_equals_vic_off(self):
        za, zb = _views(6, 16, 10)
        perm = _identity_perm(10)
        off = M.LossConfig(variant="vic_off", nu=1.0, use_pallas=False)
        grouped = M.LossConfig(variant="vic_sum", block=1, q=2, nu=1.0, use_pallas=False)
        l_off, m_off = M.loss_fn(za, zb, perm, off)
        l_g, m_g = M.loss_fn(za, zb, perm, grouped)
        assert_allclose(float(m_off["reg"]), float(m_g["reg"]), rtol=1e-3)
        assert_allclose(float(l_off), float(l_g), rtol=1e-3)

    @pytest.mark.parametrize("variant,block", [
        ("vic_off", 0), ("vic_sum", 0), ("vic_sum", 4),
    ])
    def test_pallas_equals_jnp(self, variant, block):
        za, zb = _views(7, 8, 16)
        perm = _identity_perm(16)
        base = dict(variant=variant, block=block, q=1)
        lp, _ = M.loss_fn(za, zb, perm, M.LossConfig(**base, use_pallas=True))
        lj, _ = M.loss_fn(za, zb, perm, M.LossConfig(**base, use_pallas=False))
        assert_allclose(float(lp), float(lj), rtol=1e-3)

    def test_collapsed_embeddings_penalized(self):
        # All-equal embeddings: variance hinge fires at γ per feature ×2 views.
        z = jnp.ones((16, 8), jnp.float32) * 3.0
        cfg = M.LossConfig(variant="vic_sum", gamma=1.0, use_pallas=False)
        _, m = M.loss_fn(z, z, _identity_perm(8), cfg)
        assert_allclose(float(m["var"]), 16.0, rtol=1e-3)

    def test_identical_views_zero_invariance(self):
        za, _ = _views(8, 8, 8)
        cfg = M.LossConfig(variant="vic_sum", use_pallas=False)
        _, m = M.loss_fn(za, za, _identity_perm(8), cfg)
        assert float(m["inv"]) == pytest.approx(0.0, abs=1e-6)


class TestCancellationPathology:
    """The §4.3 story, end to end on the loss functions."""

    def _adversarial_views(self, n=256, d=4, seed=0):
        # Build embeddings whose cross-correlation has the ±x wrap-diagonal
        # pattern: feature pairs correlated with alternating signs.
        rng = np.random.RandomState(seed)
        base = rng.randn(n, d).astype(np.float32)
        za = base.copy()
        zb = np.empty_like(base)
        # zb feature (i+1)%d strongly correlated with za feature i, sign (-1)^i
        for i in range(d):
            sign = 1.0 if i % 2 == 0 else -1.0
            zb[:, (i + 1) % d] = sign * base[:, i] + 0.1 * rng.randn(n)
        return jnp.asarray(za), jnp.asarray(zb)

    def test_r_sum_blind_but_r_off_sees(self):
        za, zb = self._adversarial_views()
        sa, sb = ref.standardize(za), ref.standardize(zb)
        n = za.shape[0]
        c = ref.crosscorr_ref(sa, sb, float(n))
        sv = ref.sumvec_explicit(c)
        r_sum = float(ref.r_sum_ref(sv, 2))
        r_off = float(ref.r_off_ref(c))
        assert r_off > 1.0, "individual correlations are large"
        assert r_sum < 0.1 * r_off, "but the sums cancel"

    def test_random_permutation_exposes_cancellation(self):
        # d=8: with more features, permutations that happen to preserve the
        # cancelling cyclic structure become vanishingly rare.
        za, zb = self._adversarial_views(d=8)
        sa, sb = ref.standardize(za), ref.standardize(zb)
        n = za.shape[0]
        rng = np.random.RandomState(42)
        exposed = 0
        trials = 8
        c = ref.crosscorr_ref(sa, sb, float(n))
        base = float(ref.r_sum_ref(ref.sumvec_explicit(c), 2))
        for _ in range(trials):
            perm = rng.permutation(za.shape[1])
            cp = ref.crosscorr_ref(sa[:, perm], sb[:, perm], float(n))
            if float(ref.r_sum_ref(ref.sumvec_explicit(cp), 2)) > 10 * max(base, 1e-6):
                exposed += 1
        assert exposed >= trials // 2, (
            f"random permutations should usually break the cancellation "
            f"(exposed {exposed}/{trials})"
        )


class TestGradients:
    @pytest.mark.parametrize("variant,block,q", [
        ("bt_off", 0, 2),
        ("bt_sum", 0, 2),
        ("bt_sum", 8, 2),
        ("vic_off", 0, 2),
        ("vic_sum", 0, 1),
        ("vic_sum", 8, 1),
    ])
    def test_grads_finite_and_nonzero(self, variant, block, q):
        za, zb = _views(9, 8, 16)
        perm = _identity_perm(16)
        cfg = M.LossConfig(variant=variant, block=block, q=q, use_pallas=True)

        def obj(z):
            loss, _ = M.loss_fn(z[0], z[1], perm, cfg)
            return loss

        g = jax.grad(obj)((za, zb))
        for gz in g:
            arr = np.asarray(gz)
            assert np.all(np.isfinite(arr))
            assert np.abs(arr).max() > 0

    def test_pallas_and_jnp_grads_agree(self):
        za, zb = _views(10, 8, 16)
        perm = _identity_perm(16)
        for variant in ["bt_sum", "vic_sum"]:
            gp = jax.grad(
                lambda z: M.loss_fn(
                    z[0], z[1], perm, M.LossConfig(variant=variant, use_pallas=True)
                )[0]
            )((za, zb))
            gj = jax.grad(
                lambda z: M.loss_fn(
                    z[0], z[1], perm, M.LossConfig(variant=variant, use_pallas=False)
                )[0]
            )((za, zb))
            for a, b in zip(gp, gj):
                assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
