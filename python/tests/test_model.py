"""L2 model: shapes, initialization, optimizers, and train-step sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

MLP_CFG = M.ModelConfig(
    backbone="mlp", mlp_hidden=(32,), repr_dim=16, proj_hidden=32,
    proj_layers=2, embed_dim=24,
)
CONV_CFG = M.ModelConfig(
    backbone="convnet", widths=(8, 16), repr_dim=24, proj_hidden=32,
    proj_layers=2, embed_dim=40,
)


class TestForwardShapes:
    def test_mlp_shapes(self):
        params = M.init_params(jax.random.PRNGKey(0), MLP_CFG, (10,))
        x = jnp.ones((4, 10), jnp.float32)
        r = M.representation(params, x, MLP_CFG)
        z = M.embed(params, x, MLP_CFG)
        assert r.shape == (4, 16)
        assert z.shape == (4, 24)

    def test_convnet_shapes(self):
        params = M.init_params(jax.random.PRNGKey(0), CONV_CFG, (16, 16, 3))
        x = jnp.ones((2, 16, 16, 3), jnp.float32)
        r = M.representation(params, x, CONV_CFG)
        z = M.embed(params, x, CONV_CFG)
        assert r.shape == (2, 24)
        assert z.shape == (2, 40)

    def test_different_inputs_different_embeddings(self):
        params = M.init_params(jax.random.PRNGKey(0), MLP_CFG, (10,))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(4, 10).astype(np.float32))
        z = M.embed(params, x, MLP_CFG)
        assert float(jnp.abs(z[0] - z[1]).max()) > 1e-4


class TestBatchNorm:
    def test_normalizes_batch(self):
        x = jnp.asarray(np.random.RandomState(0).randn(64, 8).astype(np.float32)) * 5 + 3
        y = M.batchnorm(x, jnp.ones(8), jnp.zeros(8), (0,))
        assert_allclose(np.asarray(y.mean(axis=0)), np.zeros(8), atol=1e-4)
        assert_allclose(np.asarray(y.std(axis=0)), np.ones(8), atol=1e-2)

    def test_scale_bias_applied(self):
        x = jnp.asarray(np.random.RandomState(1).randn(64, 4).astype(np.float32))
        y = M.batchnorm(x, 2.0 * jnp.ones(4), 7.0 * jnp.ones(4), (0,))
        assert_allclose(np.asarray(y.mean(axis=0)), 7.0 * np.ones(4), atol=1e-4)


class TestOptimizers:
    def _toy(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        grads = {"w": jnp.ones((4, 4)) * 0.1, "b": jnp.ones((4,)) * 0.1}
        return params, grads, M.init_opt_state(params)

    def test_sgd_descends(self):
        params, grads, opt = self._toy()
        cfg = M.OptConfig(optimizer="sgd", momentum=0.0, weight_decay=0.0)
        p2, _ = M.opt_update(params, grads, opt, 0.5, cfg)
        assert_allclose(np.asarray(p2["w"]), np.ones((4, 4)) - 0.05, atol=1e-6)
        assert_allclose(np.asarray(p2["b"]), -0.05 * np.ones(4), atol=1e-6)

    def test_momentum_accumulates(self):
        params, grads, opt = self._toy()
        cfg = M.OptConfig(optimizer="sgd", momentum=0.9, weight_decay=0.0)
        p1, m1 = M.opt_update(params, grads, opt, 1.0, cfg)
        p2, _ = M.opt_update(p1, grads, m1, 1.0, cfg)
        # second step is larger: v2 = 0.9*g + g = 1.9g
        step1 = np.asarray(params["w"] - p1["w"])
        step2 = np.asarray(p1["w"] - p2["w"])
        assert np.all(step2 > step1 * 1.5)

    def test_weight_decay_only_on_matrices(self):
        params, _, opt = self._toy()
        grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        cfg = M.OptConfig(optimizer="sgd", momentum=0.0, weight_decay=0.1)
        p2, _ = M.opt_update(params, grads, opt, 1.0, cfg)
        assert float(p2["w"][0, 0]) < 1.0  # decayed
        assert float(p2["b"][0]) == 0.0  # bias untouched

    def test_lars_trust_scales_update(self):
        params, grads, opt = self._toy()
        cfg = M.OptConfig(optimizer="lars", momentum=0.0, weight_decay=0.0, trust_coef=1e-3)
        p2, _ = M.opt_update(params, grads, opt, 1.0, cfg)
        # trust = 1e-3 * ||w|| / ||g|| = 1e-3 * 4 / 0.4 = 0.01 → step 0.001
        assert_allclose(np.asarray(params["w"] - p2["w"]), 0.001 * np.ones((4, 4)), rtol=1e-3)


class TestTrainStep:
    @pytest.mark.parametrize("variant", ["bt_sum", "vic_sum"])
    def test_loss_decreases_over_steps(self, variant):
        mc = MLP_CFG
        lc = M.LossConfig(variant=variant, use_pallas=False)
        oc = M.OptConfig(optimizer="sgd", momentum=0.9, weight_decay=0.0)
        step = jax.jit(M.make_train_step(mc, lc, oc))
        params = M.init_params(jax.random.PRNGKey(0), mc, (10,))
        opt = M.init_opt_state(params)
        rng = np.random.RandomState(0)
        base = rng.randn(16, 10).astype(np.float32)
        losses, invs = [], []
        key = jax.random.PRNGKey(1)
        for i in range(30):
            key, k1, k2, kp = jax.random.split(key, 4)
            xa = jnp.asarray(base) + 0.05 * jax.random.normal(k1, base.shape)
            xb = jnp.asarray(base) + 0.05 * jax.random.normal(k2, base.shape)
            perm = jax.random.permutation(kp, mc.embed_dim).astype(jnp.int32)
            params, opt, loss, inv, reg = step(params, opt, xa, xb, perm, jnp.float32(0.02))
            losses.append(float(loss))
            invs.append(float(inv))
        assert np.isfinite(losses).all()
        if variant.startswith("bt"):
            # BT loss is well-scaled at this size; expect overall descent.
            assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
        else:
            # VIC's variance hinge is noisy at n=16; the invariance term is
            # the stable progress signal.
            assert np.mean(invs[-5:]) < np.mean(invs[:5]), invs

    def test_step_changes_all_params(self):
        mc = MLP_CFG
        lc = M.LossConfig(variant="bt_sum", use_pallas=False)
        oc = M.OptConfig(optimizer="sgd", momentum=0.0, weight_decay=0.0)
        step = jax.jit(M.make_train_step(mc, lc, oc))
        params = M.init_params(jax.random.PRNGKey(0), mc, (10,))
        opt = M.init_opt_state(params)
        rng = np.random.RandomState(2)
        xa = jnp.asarray(rng.randn(16, 10).astype(np.float32))
        xb = jnp.asarray(rng.randn(16, 10).astype(np.float32))
        perm = jnp.arange(mc.embed_dim, dtype=jnp.int32)
        p2, *_ = step(params, opt, xa, xb, perm, jnp.float32(0.1))
        flat1 = jax.tree_util.tree_leaves(params)
        flat2 = jax.tree_util.tree_leaves(p2)
        changed = sum(
            float(jnp.abs(a - b).max()) > 0 for a, b in zip(flat1, flat2)
        )
        assert changed >= len(flat1) - 1  # everything but possibly one BN leaf
