"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and seeds; every kernel is asserted against its
``ref.py`` oracle with ``assert_allclose``. This is the core correctness
signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import sumvec as K

jax.config.update("jax_platform_name", "cpu")


def _views(seed, n, d):
    rng = np.random.RandomState(seed)
    za = rng.randn(n, d).astype(np.float32)
    zb = rng.randn(n, d).astype(np.float32)
    return jnp.asarray(za), jnp.asarray(zb)


# ---------------------------------------------------------------------- FFT
class TestSumvecAlgebra:
    """Eq. (12) algebra: the FFT path equals the explicit Eq. (5) path."""

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(2, 16),
        d=st.sampled_from([4, 6, 8, 16, 32]),
    )
    def test_fft_ref_matches_explicit(self, seed, n, d):
        za, zb = _views(seed, n, d)
        c = ref.crosscorr_ref(za, zb, float(n))
        explicit = ref.sumvec_explicit(c)
        fft_path = ref.sumvec_fft_ref(za, zb, float(n))
        assert_allclose(np.asarray(fft_path), np.asarray(explicit), atol=1e-4)

    def test_sumvec_zeroth_is_trace(self):
        za, zb = _views(0, 8, 16)
        c = ref.crosscorr_ref(za, zb, 8.0)
        sv = ref.sumvec_explicit(c)
        assert_allclose(float(sv[0]), float(jnp.trace(c)), atol=1e-5)

    def test_sumvec_partitions_matrix(self):
        # Each element of C contributes to exactly one sumvec component.
        za, zb = _views(1, 4, 8)
        c = ref.crosscorr_ref(za, zb, 4.0)
        sv = ref.sumvec_explicit(c)
        assert_allclose(float(jnp.sum(sv)), float(jnp.sum(c)), rtol=1e-4)


class TestSpectralReduce:
    """Pallas spectral_reduce vs the jnp oracle."""

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(1, 12),
        d=st.sampled_from([4, 8, 16, 64, 130]),
    )
    def test_matches_ref(self, seed, n, d):
        za, zb = _views(seed, n, d)
        got = K.sumvec_pallas(za, zb, float(n), use_pallas=True)
        want = ref.sumvec_fft_ref(za, zb, float(n))
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_block_smaller_than_bins(self):
        za, zb = _views(3, 8, 256)
        got = K.sumvec_pallas(za, zb, 8.0, block_f=32)
        want = ref.sumvec_fft_ref(za, zb, 8.0)
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)

    def test_ragged_frequency_padding(self):
        # F = d/2+1 = 33 bins with block 8 -> padding path exercised.
        za, zb = _views(4, 5, 64)
        got = K.sumvec_pallas(za, zb, 5.0, block_f=8)
        want = ref.sumvec_fft_ref(za, zb, 5.0)
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


class TestGroupedSpectralReduce:
    """Grouped kernel vs the einsum oracle and Eq. (13) semantics."""

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(2, 8),
        block=st.sampled_from([2, 4, 8]),
        groups=st.integers(1, 4),
    )
    def test_matches_einsum(self, seed, n, block, groups):
        d = block * groups
        za, zb = _views(seed, n, d)
        ga = ref.group_pad(za, block)
        gb = ref.group_pad(zb, block)
        fa = jnp.fft.rfft(ga, axis=2)
        fb = jnp.fft.rfft(gb, axis=2)
        got_re, got_im = K.grouped_spectral_reduce(
            jnp.real(fa), jnp.imag(fa), jnp.real(fb), jnp.imag(fb), use_pallas=True
        )
        want_re, want_im = K.grouped_spectral_reduce(
            jnp.real(fa), jnp.imag(fa), jnp.real(fb), jnp.imag(fb), use_pallas=False
        )
        assert_allclose(np.asarray(got_re), np.asarray(want_re), atol=1e-4)
        assert_allclose(np.asarray(got_im), np.asarray(want_im), atol=1e-4)

    def test_grouped_b_equals_d_is_flat_sumvec(self):
        # R_sum^(d) == R_sum (paper §4.4).
        za, zb = _views(7, 6, 16)
        flat = ref.sumvec_fft_ref(za, zb, 6.0)
        grouped = ref.sumvec_grouped_fft_ref(za, zb, 16, 6.0)
        assert grouped.shape == (1, 1, 16)
        assert_allclose(np.asarray(grouped[0, 0]), np.asarray(flat), atol=1e-4)

    def test_grouped_b1_q2_equals_r_off(self):
        # R_sum^(1) with q=2 == R_off (paper §4.4).
        za, zb = _views(8, 6, 10)
        c = ref.crosscorr_ref(za, zb, 6.0)
        got = ref.r_sum_grouped_ref(za, zb, 1, 2, 6.0)
        want = ref.r_off_ref(c)
        assert_allclose(float(got), float(want), rtol=1e-4)

    def test_ragged_group_padding(self):
        # d=10, block=4 -> last group zero-padded; regularizer must treat
        # pad features as constant-zero (no contribution).
        za, zb = _views(9, 5, 10)
        got = ref.r_sum_grouped_ref(za, zb, 4, 2, 5.0)
        assert np.isfinite(float(got))


# ------------------------------------------------------------------- matmul
class TestCrosscorr:
    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.sampled_from([2, 5, 16, 130]),
        d=st.sampled_from([4, 16, 33, 130]),
    )
    def test_matches_ref(self, seed, n, d):
        za, zb = _views(seed, n, d)
        got = K.crosscorr(za, zb, float(n), block_m=32, block_n=32, block_k=32)
        want = ref.crosscorr_ref(za, zb, float(n))
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)

    def test_mxu_tiles_on_large_d(self):
        za, zb = _views(11, 64, 256)
        got = K.crosscorr(za, zb, 64.0)  # default 128-tiles
        want = ref.crosscorr_ref(za, zb, 64.0)
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


class TestOffdiagSq:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([4, 16, 33, 96]))
    def test_matches_ref(self, seed, d):
        rng = np.random.RandomState(seed)
        m = jnp.asarray(rng.randn(d, d).astype(np.float32))
        got = K.offdiag_sq(m, block_m=16, block_n=16)
        want = ref.r_off_ref(m)
        assert_allclose(float(got), float(want), rtol=1e-4)

    def test_diagonal_matrix_gives_zero(self):
        m = jnp.diag(jnp.arange(1.0, 9.0, dtype=jnp.float32))
        assert float(K.offdiag_sq(m, block_m=4, block_n=4)) == pytest.approx(0.0)

    def test_paper_cancellation_example(self):
        # The §4.3 pathology: wrap-diagonal ±x cancels in sumvec but not
        # in R_off.
        d = 4
        m = np.zeros((d, d), np.float32)
        m[0, 1], m[1, 2], m[2, 3], m[3, 0] = 0.9, -0.9, 0.9, -0.9
        m = jnp.asarray(m)
        sv = ref.sumvec_explicit(m)
        assert float(ref.r_sum_ref(sv, 2)) == pytest.approx(0.0, abs=1e-10)
        assert float(K.offdiag_sq(m, block_m=2, block_n=2)) == pytest.approx(
            4 * 0.81, rel=1e-5
        )
