"""Scan-fused multi-step semantics (§Perf artifact `trainmulti_*`):
K steps under `lax.scan` must equal K sequential single-step calls.
"""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

MC = M.ModelConfig(
    backbone="mlp", mlp_hidden=(16,), repr_dim=8, proj_hidden=16,
    proj_layers=2, embed_dim=12,
)
LC = M.LossConfig(variant="bt_sum", use_pallas=False)
OC = M.OptConfig(optimizer="lars", momentum=0.9, weight_decay=1e-4)


def _scan_steps(params, opt, xas, xbs, perms, lrs):
    def body(carry, inputs):
        p, o = carry
        xa, xb, perm, lr = inputs

        def objective(pp):
            za = M.embed(pp, xa, MC)
            zb = M.embed(pp, xb, MC)
            return M.loss_fn(za, zb, perm, LC)

        (loss, _), grads = jax.value_and_grad(objective, has_aux=True)(p)
        p2, o2 = M.opt_update(p, grads, o, lr, OC)
        return (p2, o2), loss

    (pf, of), losses = jax.lax.scan(body, (params, opt), (xas, xbs, perms, lrs))
    return pf, of, losses


class TestMultiStepEquivalence:
    def test_scan_equals_sequential(self):
        k, n, f = 5, 8, 6
        rng = np.random.RandomState(0)
        params = M.init_params(jax.random.PRNGKey(0), MC, (f,))
        opt = M.init_opt_state(params)
        xas = jnp.asarray(rng.randn(k, n, f).astype(np.float32))
        xbs = jnp.asarray(rng.randn(k, n, f).astype(np.float32))
        perms = jnp.stack(
            [jnp.asarray(rng.permutation(MC.embed_dim).astype(np.int32)) for _ in range(k)]
        )
        lrs = jnp.asarray(np.linspace(0.1, 0.05, k).astype(np.float32))

        # Sequential reference.
        step = M.make_train_step(MC, LC, OC)
        p_seq, o_seq = params, opt
        seq_losses = []
        for i in range(k):
            p_seq, o_seq, loss, _, _ = step(p_seq, o_seq, xas[i], xbs[i], perms[i], lrs[i])
            seq_losses.append(float(loss))

        # Scan-fused.
        p_scan, o_scan, losses = jax.jit(_scan_steps)(params, opt, xas, xbs, perms, lrs)

        assert_allclose(np.asarray(losses), np.asarray(seq_losses), rtol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(p_seq), jax.tree_util.tree_leaves(p_scan)):
            assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(o_seq), jax.tree_util.tree_leaves(o_scan)):
            assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_scan_losses_descend_on_fixed_batch(self):
        k, n, f = 12, 16, 6
        rng = np.random.RandomState(1)
        params = M.init_params(jax.random.PRNGKey(1), MC, (f,))
        opt = M.init_opt_state(params)
        base = rng.randn(n, f).astype(np.float32)
        xas = jnp.asarray(np.repeat(base[None], k, axis=0))
        xbs = xas + 0.01
        perms = jnp.stack([jnp.arange(MC.embed_dim, dtype=jnp.int32)] * k)
        lrs = jnp.full((k,), 0.05, jnp.float32)
        _, _, losses = jax.jit(_scan_steps)(params, opt, xas, xbs, perms, lrs)
        losses = np.asarray(losses)
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0]


class TestGradClipping:
    def test_large_grads_are_clipped(self):
        params = {"w": jnp.ones((4, 4))}
        opt = M.init_opt_state(params)
        huge = {"w": jnp.full((4, 4), 1e6)}
        cfg = M.OptConfig(optimizer="sgd", momentum=0.0, weight_decay=0.0, clip_norm=1.0)
        p2, _ = M.opt_update(params, huge, opt, 1.0, cfg)
        step = np.asarray(params["w"] - p2["w"])
        # global norm of applied update == clip_norm
        assert abs(np.sqrt((step**2).sum()) - 1.0) < 1e-4

    def test_small_grads_untouched(self):
        params = {"w": jnp.ones((2, 2))}
        opt = M.init_opt_state(params)
        g = {"w": jnp.full((2, 2), 0.1)}
        cfg = M.OptConfig(optimizer="sgd", momentum=0.0, weight_decay=0.0, clip_norm=10.0)
        p2, _ = M.opt_update(params, g, opt, 1.0, cfg)
        assert_allclose(np.asarray(params["w"] - p2["w"]), 0.1 * np.ones((2, 2)), rtol=1e-5)
