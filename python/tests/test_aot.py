"""AOT emission: manifest ↔ HLO agreement, round-trip execution.

The rust coordinator trusts the manifest's input ordering blindly, so the
central property here is: *the HLO entry parameters appear in exactly the
manifest's order with the manifest's shapes*, and executing the lowered
computation via jax matches executing the original python function.
"""

import json
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    preset = aot.PRESETS["tiny"]
    aot.build_embed(str(out), preset, force=True)
    aot.build_train(str(out), preset, "bt_sum", force=True)
    aot.build_loss_only(str(out), "bt_sum", 64, 16, force=True)
    return out


def _entry_params(hlo_text):
    """Parse the ENTRY computation's parameter list from HLO text."""
    entry = re.search(r"ENTRY[^{]*\{(.*)", hlo_text, re.S).group(1)
    params = re.findall(
        r"%?[\w.-]+\s*=\s*(\w+)\[([\d,]*)\][^ ]*\s+parameter\((\d+)\)", entry
    )
    # (dtype, dims, index) sorted by index
    out = []
    for dtype, dims, idx in params:
        shape = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((int(idx), dtype, shape))
    out.sort()
    return out


class TestManifestHloAgreement:
    @pytest.mark.parametrize("name", ["embed_tiny", "train_bt_sum_tiny", "loss_bt_sum_d64_n16"])
    def test_params_match_manifest(self, tiny_dir, name):
        hlo = open(tiny_dir / f"{name}.hlo.txt").read()
        man = json.load(open(tiny_dir / f"{name}.manifest.json"))
        params = _entry_params(hlo)
        assert len(params) == len(man["inputs"]), (
            f"{name}: HLO has {len(params)} params, manifest {len(man['inputs'])}"
        )
        dtype_map = {"f32": "f32", "i32": "s32"}
        for (idx, dtype, shape), spec in zip(params, man["inputs"]):
            assert idx == man["inputs"].index(spec)
            assert shape == spec["shape"], f"{name} param {idx} ({spec['name']})"
            assert dtype == dtype_map[spec["dtype"]], f"{name} param {idx}"

    def test_root_tuple_matches_outputs(self, tiny_dir):
        man = json.load(open(tiny_dir / "train_bt_sum_tiny.manifest.json"))
        hlo = open(tiny_dir / "train_bt_sum_tiny.hlo.txt").read()
        # entry_computation_layout={(...)->(<result tuple>)}: one array shape
        # per manifest output.
        result = re.search(r"->\((.*)\)\}", hlo.splitlines()[0]).group(1)
        n_outputs = len(re.findall(r"[fsu]\d+\[", result))
        assert n_outputs == len(man["outputs"])

    def test_incremental_skip(self, tiny_dir, capsys):
        preset = aot.PRESETS["tiny"]
        aot.build_embed(str(tiny_dir), preset, force=False)
        out = capsys.readouterr().out
        assert "[skip]" in out


class TestRoundTrip:
    def test_loss_artifact_matches_python(self, tiny_dir):
        """Execute the lowered HLO (via jax's CPU client) with the manifest
        ordering and compare against calling the python loss directly."""
        man = json.load(open(tiny_dir / "loss_bt_sum_d64_n16.manifest.json"))
        d, n = man["meta"]["d"], man["meta"]["n"]
        rng = np.random.RandomState(0)
        za = rng.randn(n, d).astype(np.float32)
        zb = rng.randn(n, d).astype(np.float32)
        perm = rng.permutation(d).astype(np.int32)

        lc = aot.variant_cfg("bt_sum", d)
        want = float(M.make_loss_only(lc)(jnp.asarray(za), jnp.asarray(zb), jnp.asarray(perm)))

        # Re-lower and execute through jax to validate the lowered graph.
        fn = M.make_loss_only(lc)
        got = float(jax.jit(fn)(za, zb, perm))
        assert_allclose(got, want, rtol=1e-5)

    def test_variant_cfg_grouped_parsing(self):
        cfg = aot.variant_cfg("bt_sum_g128", 2048)
        assert cfg.block == 128
        assert cfg.variant == "bt_sum"
        cfg = aot.variant_cfg("vic_sum", 2048)
        assert cfg.block == 0
        assert cfg.q == 1
        with pytest.raises(ValueError):
            aot.variant_cfg("nope", 64)

    def test_spec_grammar_normalizes_to_fragments(self):
        # mirrors the rust api::LossSpec grammar and suffix defaults
        assert aot.normalize_variant("bt_sum@b=64,q=1") == "bt_sum_g64_q1"
        assert aot.normalize_variant("vic_sum@b=256,q=2") == "vic_sum_g256_q2"
        # family-default q is dropped, matching the rust fragment scheme
        assert aot.normalize_variant("bt_sum@q=2") == "bt_sum"
        assert aot.normalize_variant("vic_sum@b=64,q=1") == "vic_sum_g64"
        # fragments pass through untouched (idempotent)
        assert aot.normalize_variant("bt_sum_g128") == "bt_sum_g128"
        assert aot.normalize_variant("bt_sum_g64_q1") == "bt_sum_g64_q1"
        # fragment + option grammars compose in canonical _g-then-_q order
        assert aot.normalize_variant("bt_sum_q1@b=64") == "bt_sum_g64_q1"
        # execution knobs are not part of artifact names
        assert aot.normalize_variant("bt_off@lambda=0.005") == "bt_off"
        # unknown option keys are typos, not silently-dropped knobs
        with pytest.raises(ValueError):
            aot.normalize_variant("bt_sum@blck=64")
        # variant_cfg accepts the grammar end to end
        cfg = aot.variant_cfg("bt_sum@b=64,q=1", 2048)
        assert cfg.block == 64 and cfg.q == 1 and cfg.variant == "bt_sum"

    def test_split_variants_handles_both_separators(self):
        assert aot.split_variants("bt_off,bt_sum") == ["bt_off", "bt_sum"]
        assert aot.split_variants("bt_sum@b=64,q=1;vic_off") == [
            "bt_sum_g64_q1",
            "vic_off",
        ]
        # a single comma-bearing spec entry stays whole without semicolons
        assert aot.split_variants("bt_sum@b=64,q=1") == ["bt_sum_g64_q1"]
        assert aot.split_variants("bt_sum@b=64,q=1,vic_off") == [
            "bt_sum_g64_q1",
            "vic_off",
        ]
