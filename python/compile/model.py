"""L2: the JAX SSL model — backbone, projector, losses, optimizer, AOT steps.

This is the build-time compute-graph layer. Everything here is lowered once
by ``aot.py`` into HLO-text artifacts; Python never runs on the training
path (the rust coordinator executes the artifacts via PJRT).

Components
----------
* ``SmallConvNet`` / ``MlpBackbone`` — CPU-scale stand-ins for the paper's
  ResNet-18/50 (the loss-node claims are backbone-agnostic; DESIGN.md
  documents the substitution).
* ``projector``   — BT/VICReg-style MLP head producing d-dim embeddings.
* Loss family     — ``bt_off`` (orig. Barlow Twins, Eq. 1), ``vic_off``
  (orig. VICReg, Eq. 3), ``bt_sum`` / ``vic_sum`` (the proposed FFT
  regularizers, Eqs. 14/15), each with optional feature grouping (Eq. 13)
  and the per-batch feature permutation of §4.3.
* Optimizers      — SGD+momentum and LARS (the paper trains with LARS).
* ``make_train_step`` — one optimizer step (fwd + bwd + update) as a pure
  function ``(params, opt_state, xa, xb, perm, lr) -> (params', opt_state',
  metrics)`` ready for AOT lowering.

Dict keys are kept sorted-stable so jax's pytree flattening order (and
hence the artifact manifest) is deterministic.
"""

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import sumvec as K

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Backbone + projector shape."""

    backbone: str = "convnet"  # "convnet" | "mlp"
    image_size: int = 32
    channels: int = 3
    widths: Tuple[int, ...] = (32, 64, 128, 256)  # conv channel plan
    mlp_hidden: Tuple[int, ...] = (512, 512)  # mlp backbone plan
    repr_dim: int = 256  # backbone output dim
    proj_hidden: int = 1024
    proj_layers: int = 3
    embed_dim: int = 2048  # d — the projected embedding dim


@dataclasses.dataclass(frozen=True)
class LossConfig:
    """Which regularizer family and its hyperparameters."""

    variant: str = "bt_sum"  # bt_off | bt_sum | vic_off | vic_sum
    block: int = 0  # feature-grouping block size; 0 = no grouping
    q: int = 2  # L_q^q norm exponent for R_sum
    lam: float = 2.0**-10  # λ (BT family)
    alpha: float = 25.0  # α (VIC family invariance)
    mu: float = 25.0  # μ (VIC family variance)
    nu: float = 1.0  # ν (VIC family covariance)
    gamma: float = 1.0  # target std in R_var
    scale: float = 0.125  # overall loss scale
    use_pallas: bool = True  # route hot loops through the Pallas kernels


@dataclasses.dataclass(frozen=True)
class OptConfig:
    """Optimizer selection; the paper uses LARS with SGD momentum."""

    optimizer: str = "lars"  # "sgd" | "lars"
    momentum: float = 0.9
    weight_decay: float = 1e-4
    trust_coef: float = 1e-3  # LARS trust coefficient (η)
    clip_norm: float = 10.0  # global grad-norm clip; 0 disables


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def _he_init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def batchnorm(x, scale, bias, axes, eps=1e-5):
    """Train-mode batch normalization over ``axes`` (no running stats —
    SSL pretraining normalizes per batch, like the BT/VICReg reference)."""
    mean = x.mean(axis=axes, keepdims=True)
    var = x.var(axis=axes, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * scale + bias


def conv3x3(x, w):
    """3×3 same-padding convolution, NHWC · HWIO."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


# ---------------------------------------------------------------------------
# Backbones
# ---------------------------------------------------------------------------


def init_convnet(key, cfg: ModelConfig) -> Dict[str, Any]:
    """Conv(3×3)+BN+ReLU+pool stack ending in global average pooling, then a
    linear map to ``repr_dim``. ~1–3 M params at the default widths."""
    params = {}
    c_in = cfg.channels
    for i, c_out in enumerate(cfg.widths):
        key, k1 = jax.random.split(key)
        params[f"conv{i}_w"] = _he_init(k1, (3, 3, c_in, c_out), 9 * c_in)
        params[f"conv{i}_bn_s"] = jnp.ones((c_out,), jnp.float32)
        params[f"conv{i}_bn_b"] = jnp.zeros((c_out,), jnp.float32)
        c_in = c_out
    key, k1 = jax.random.split(key)
    params["head_w"] = _he_init(k1, (c_in, cfg.repr_dim), c_in)
    params["head_b"] = jnp.zeros((cfg.repr_dim,), jnp.float32)
    return params


def convnet_forward(params, x, cfg: ModelConfig):
    """x: (n, H, W, C) → representation (n, repr_dim)."""
    h = x
    for i in range(len(cfg.widths)):
        h = conv3x3(h, params[f"conv{i}_w"])
        h = batchnorm(h, params[f"conv{i}_bn_s"], params[f"conv{i}_bn_b"], (0, 1, 2))
        h = jax.nn.relu(h)
        if i < len(cfg.widths) - 1:
            h = maxpool2(h)
    h = h.mean(axis=(1, 2))  # global average pool
    return h @ params["head_w"] + params["head_b"]


def init_mlp_backbone(key, cfg: ModelConfig, in_dim: int) -> Dict[str, Any]:
    """Flat-input MLP backbone (benchmarks / tiny presets)."""
    params = {}
    dims = [in_dim, *cfg.mlp_hidden, cfg.repr_dim]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        params[f"fc{i}_w"] = _he_init(k1, (a, b), a)
        params[f"fc{i}_b"] = jnp.zeros((b,), jnp.float32)
    return params


def mlp_backbone_forward(params, x, cfg: ModelConfig):
    h = x.reshape(x.shape[0], -1)
    n_layers = len(cfg.mlp_hidden) + 1
    for i in range(n_layers):
        h = h @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Projector
# ---------------------------------------------------------------------------


def init_projector(key, cfg: ModelConfig) -> Dict[str, Any]:
    """BT-style projector: (repr → h)·BN·ReLU ×(L−1), then h → d."""
    params = {}
    dims = [cfg.repr_dim] + [cfg.proj_hidden] * (cfg.proj_layers - 1) + [cfg.embed_dim]
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        params[f"proj{i}_w"] = _he_init(k1, (a, b), a)
        params[f"proj{i}_b"] = jnp.zeros((b,), jnp.float32)
        if i < len(dims) - 2:
            params[f"proj{i}_bn_s"] = jnp.ones((b,), jnp.float32)
            params[f"proj{i}_bn_b"] = jnp.zeros((b,), jnp.float32)
    return params


def projector_forward(params, h, cfg: ModelConfig):
    n_layers = cfg.proj_layers
    for i in range(n_layers):
        h = h @ params[f"proj{i}_w"] + params[f"proj{i}_b"]
        if i < n_layers - 1:
            h = batchnorm(h, params[f"proj{i}_bn_s"], params[f"proj{i}_bn_b"], (0,))
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig, in_shape) -> Dict[str, Any]:
    """Initialize {'backbone': …, 'projector': …} for input shape
    (H, W, C) (convnet) or (features,) (mlp)."""
    kb, kp = jax.random.split(key)
    if cfg.backbone == "convnet":
        backbone = init_convnet(kb, cfg)
    elif cfg.backbone == "mlp":
        in_dim = 1
        for s in in_shape:
            in_dim *= s
        backbone = init_mlp_backbone(kb, cfg, in_dim)
    else:
        raise ValueError(f"unknown backbone {cfg.backbone}")
    return {"backbone": backbone, "projector": init_projector(kp, cfg)}


def representation(params, x, cfg: ModelConfig):
    """Backbone output (the features reused downstream)."""
    if cfg.backbone == "convnet":
        return convnet_forward(params["backbone"], x, cfg)
    return mlp_backbone_forward(params["backbone"], x, cfg)


def embed(params, x, cfg: ModelConfig):
    """Projected embedding z = projector(backbone(x)) — the loss input."""
    return projector_forward(params["projector"], representation(params, x, cfg), cfg)


# ---------------------------------------------------------------------------
# Losses (operate on projected embeddings za, zb of shape (n, d))
# ---------------------------------------------------------------------------


def _permute(z, perm):
    return jnp.take(z, perm, axis=1)


def _r_sum_flat(za, zb, norm, q, use_pallas):
    sv = (
        K.sumvec_pallas(za, zb, norm)
        if use_pallas
        else ref.sumvec_fft_ref(za, zb, norm)
    )
    return ref.r_sum_ref(sv, q)


def _r_sum_grouped(za, zb, block, norm, q, use_pallas):
    ga = ref.group_pad(za, block)
    gb = ref.group_pad(zb, block)
    fa = jnp.fft.rfft(ga, axis=2)
    fb = jnp.fft.rfft(gb, axis=2)
    acc_re, acc_im = K.grouped_spectral_reduce(
        jnp.real(fa), jnp.imag(fa), jnp.real(fb), jnp.imag(fb),
        use_pallas=use_pallas,
    )
    sv = jnp.fft.irfft(jax.lax.complex(acc_re, acc_im), n=block, axis=2) / norm
    groups = sv.shape[0]
    absq = jnp.abs(sv) if q == 1 else sv**2
    comp0 = jnp.zeros((sv.shape[2],), sv.dtype).at[0].set(1.0)
    mask = 1.0 - jnp.eye(groups, dtype=sv.dtype)[:, :, None] * comp0[None, None, :]
    return jnp.sum(absq * mask)


def bt_loss(za, zb, perm, cfg: LossConfig):
    """Barlow Twins-family loss (Eqs. 1/14). Returns (loss, metrics)."""
    n = za.shape[0]
    za = ref.standardize(za)
    zb = ref.standardize(zb)
    za = _permute(za, perm)
    zb = _permute(zb, perm)
    inv = ref.diag_invariance_ref(za, zb, float(n))
    if cfg.variant == "bt_off":
        c = (
            K.crosscorr(za, zb, float(n))
            if cfg.use_pallas
            else ref.crosscorr_ref(za, zb, float(n))
        )
        reg = (
            K.offdiag_sq(c) if cfg.use_pallas else ref.r_off_ref(c)
        )
    elif cfg.block > 0:
        reg = _r_sum_grouped(za, zb, cfg.block, float(n), cfg.q, cfg.use_pallas)
    else:
        reg = _r_sum_flat(za, zb, float(n), cfg.q, cfg.use_pallas)
    loss = cfg.scale * (inv + cfg.lam * reg)
    return loss, {"inv": inv, "reg": reg}


def vic_loss(za, zb, perm, cfg: LossConfig):
    """VICReg-family loss (Eqs. 3/15). Returns (loss, metrics)."""
    n = za.shape[0]
    norm = float(max(n - 1, 1))
    inv = jnp.sum((za - zb) ** 2) / n
    za = _permute(za, perm)
    zb = _permute(zb, perm)
    ca = za - za.mean(axis=0, keepdims=True)
    cb = zb - zb.mean(axis=0, keepdims=True)
    var_a = jnp.sum(jnp.maximum(0.0, cfg.gamma - jnp.sqrt(jnp.mean(ca**2, axis=0) * n / norm + 1e-8)))
    var_b = jnp.sum(jnp.maximum(0.0, cfg.gamma - jnp.sqrt(jnp.mean(cb**2, axis=0) * n / norm + 1e-8)))
    if cfg.variant == "vic_off":
        if cfg.use_pallas:
            ka = K.crosscorr(ca, ca, norm)
            kb = K.crosscorr(cb, cb, norm)
            reg = K.offdiag_sq(ka) + K.offdiag_sq(kb)
        else:
            ka = ref.crosscorr_ref(ca, ca, norm)
            kb = ref.crosscorr_ref(cb, cb, norm)
            reg = ref.r_off_ref(ka) + ref.r_off_ref(kb)
    elif cfg.block > 0:
        reg = _r_sum_grouped(ca, ca, cfg.block, norm, cfg.q, cfg.use_pallas) + _r_sum_grouped(
            cb, cb, cfg.block, norm, cfg.q, cfg.use_pallas
        )
    else:
        reg = _r_sum_flat(ca, ca, norm, cfg.q, cfg.use_pallas) + _r_sum_flat(
            cb, cb, norm, cfg.q, cfg.use_pallas
        )
    d = za.shape[1]
    var = var_a + var_b
    # Eq. (3)/(15): (α/n)·Σ‖a−b‖² + (μ/d)·(R_var A + R_var B) + (ν/d)·reg;
    # `inv` already carries the 1/n.
    loss = cfg.alpha * inv + cfg.mu / d * var + cfg.nu / d * reg
    return loss, {"inv": inv, "reg": reg, "var": var}


def loss_fn(za, zb, perm, cfg: LossConfig):
    """Dispatch on the loss family."""
    if cfg.variant.startswith("bt"):
        return bt_loss(za, zb, perm, cfg)
    if cfg.variant.startswith("vic"):
        return vic_loss(za, zb, perm, cfg)
    raise ValueError(f"unknown loss variant {cfg.variant}")


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def init_opt_state(params):
    """Momentum buffers, one per parameter leaf."""
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def _is_matrix(p):
    return p.ndim >= 2


def opt_update(params, grads, opt_state, lr, cfg: OptConfig):
    """One SGD-momentum or LARS step. BN scales/biases (ndim < 2) are
    excluded from weight decay and LARS adaptation, as is standard.
    Gradients are globally norm-clipped first (the VIC loss can spike at
    large d before the variance hinge settles)."""

    if cfg.clip_norm > 0:
        gnorm = jnp.sqrt(
            sum(jnp.sum(g**2) for g in jax.tree_util.tree_leaves(grads)) + 1e-12
        )
        factor = jnp.minimum(1.0, cfg.clip_norm / gnorm)
        grads = jax.tree_util.tree_map(lambda g: g * factor, grads)

    def leaf(p, g, m):
        wd = cfg.weight_decay if _is_matrix(p) else 0.0
        g = g + wd * p
        if cfg.optimizer == "lars" and _is_matrix(p):
            p_norm = jnp.linalg.norm(p)
            g_norm = jnp.linalg.norm(g)
            trust = jnp.where(
                (p_norm > 0.0) & (g_norm > 0.0),
                cfg.trust_coef * p_norm / (g_norm + 1e-9),
                1.0,
            )
            g = g * trust
        m_new = cfg.momentum * m + g
        p_new = p - lr * m_new
        return p_new, m_new

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state)
    new_p, new_m = [], []
    for p, g, m in zip(flat_p, flat_g, flat_m):
        pn, mn = leaf(p, g, m)
        new_p.append(pn)
        new_m.append(mn)
    return tree.unflatten(new_p), tree.unflatten(new_m)


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------


def make_train_step(model_cfg: ModelConfig, loss_cfg: LossConfig, opt_cfg: OptConfig):
    """Build the pure train-step function for AOT lowering.

    Signature: (params, opt_state, xa, xb, perm, lr)
             → (params', opt_state', loss, inv, reg)
    """

    def step(params, opt_state, xa, xb, perm, lr):
        def objective(p):
            za = embed(p, xa, model_cfg)
            zb = embed(p, xb, model_cfg)
            return loss_fn(za, zb, perm, loss_cfg)

        (loss, metrics), grads = jax.value_and_grad(objective, has_aux=True)(params)
        new_params, new_opt = opt_update(params, grads, opt_state, lr, opt_cfg)
        return new_params, new_opt, loss, metrics["inv"], metrics["reg"]

    return step


def make_embed(model_cfg: ModelConfig):
    """Frozen feature extractor: (params, x) → backbone representation."""

    def fn(params, x):
        return representation(params, x, model_cfg)

    return fn


def make_project(model_cfg: ModelConfig):
    """(params, x) → projected embedding z (for Table-6 diagnostics)."""

    def fn(params, x):
        return embed(params, x, model_cfg)

    return fn


def make_loss_only(loss_cfg: LossConfig):
    """Loss on raw embeddings (za, zb, perm) → scalar — the Fig. 2 / Tab. 12
    forward-loss timing workload, isolated from the backbone."""

    def fn(za, zb, perm):
        loss, _ = loss_fn(za, zb, perm, loss_cfg)
        return loss

    return fn


def make_loss_grad(loss_cfg: LossConfig):
    """Loss + gradient wrt embeddings — the backward-pass timing workload
    (Tab. 12/13): grads flow through the loss node exactly as they would
    into the projector."""

    def fn(za, zb, perm):
        def obj(z2):
            loss, _ = loss_fn(z2[0], z2[1], perm, loss_cfg)
            return loss

        loss, grads = jax.value_and_grad(obj)((za, zb))
        return loss, grads[0], grads[1]

    return fn
