"""Pure-jnp correctness oracles for the L1 Pallas kernels and L2 losses.

Every quantity in the paper has a direct, readable implementation here:

* ``crosscorr_ref``      — C(A, B) = A^T B / norm                    (§3)
* ``covariance_ref``     — K(A) with column centering                (§3)
* ``r_off_ref``          — Eq. (2), sum of squared off-diagonals
* ``r_var_ref``          — Eq. (4), variance hinge
* ``sumvec_explicit``    — Eq. (5), wrap-diagonal sums of a matrix
* ``sumvec_fft_ref``     — Eq. (12), the FFT path (no Pallas)
* ``r_sum_ref``          — Eq. (6)
* ``r_sum_grouped_ref``  — Eq. (13), block-grouped variant
* ``offdiag_sq_ref``     — same reduction the offdiag Pallas kernel does

The pytest suites assert the Pallas kernels (``sumvec.py``) and the lowered
L2 losses (``model.py``) against these, element-for-element.
"""

import jax.numpy as jnp


def standardize(z, eps=1e-5):
    """Column-standardize a batch: zero mean, unit std per feature.

    Mirrors ``batch_normalization`` in the paper's Listing 1 (the
    preprocessing before the cross-correlation regularizer).
    """
    mean = z.mean(axis=0, keepdims=True)
    std = z.std(axis=0, keepdims=True)
    return (z - mean) / jnp.maximum(std, eps)


def crosscorr_ref(za, zb, norm):
    """Cross-correlation matrix C = za^T zb / norm (inputs standardized)."""
    return (za.T @ zb) / norm


def covariance_ref(z):
    """Covariance matrix K = centered(z)^T centered(z) / (n - 1)."""
    n = z.shape[0]
    zc = z - z.mean(axis=0, keepdims=True)
    return (zc.T @ zc) / max(n - 1, 1)


def r_off_ref(m):
    """Eq. (2): sum of squared off-diagonal elements."""
    d = m.shape[0]
    mask = 1.0 - jnp.eye(d, dtype=m.dtype)
    return jnp.sum((m * mask) ** 2)


def r_var_ref(m, gamma=1.0, eps=1e-8):
    """Eq. (4): sum_i max(0, gamma - sqrt(M_ii))."""
    diag = jnp.clip(jnp.diag(m), 0.0, None)
    return jnp.sum(jnp.maximum(0.0, gamma - jnp.sqrt(diag + eps)))


def sumvec_explicit(m):
    """Eq. (5): sumvec(M)_i = sum_j M[j, (i+j) mod d], via explicit rolls.

    O(d^2) — the oracle for the FFT path.
    """
    d = m.shape[0]
    rows = [jnp.trace(jnp.roll(m, shift=-i, axis=1)) for i in range(d)]
    return jnp.stack(rows)


def sumvec_fft_ref(za, zb, norm):
    """Eq. (12): sumvec(C) = irfft( sum_k conj(rfft(a_k)) * rfft(b_k) ) / norm.

    Pure-jnp (no Pallas) — validates both the algebra (against
    ``sumvec_explicit``) and the Pallas kernel (against this).
    """
    d = za.shape[1]
    fa = jnp.fft.rfft(za, axis=1)
    fb = jnp.fft.rfft(zb, axis=1)
    acc = jnp.sum(jnp.conj(fa) * fb, axis=0)
    return jnp.fft.irfft(acc, n=d, axis=0) / norm


def r_sum_ref(sumvec, q):
    """Eq. (6): all-but-zeroth components of sumvec under the q-norm."""
    tail = sumvec[1:]
    if q == 1:
        return jnp.sum(jnp.abs(tail))
    return jnp.sum(tail**2)


def group_pad(z, block):
    """Split features into ceil(d/block) groups of size `block`, zero-padding
    the ragged last group (paper §4.4 footnote 4). Returns (n, G, block)."""
    n, d = z.shape
    groups = -(-d // block)
    pad = groups * block - d
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
    return z.reshape(n, groups, block)


def sumvec_grouped_fft_ref(za, zb, block, norm):
    """Per-block-pair summary vectors via FFT: (G, G, block) tensor where
    entry [gi, gj] is sumvec(C_{gi,gj})."""
    ga = group_pad(za, block)  # (n, G, b)
    gb = group_pad(zb, block)
    fa = jnp.fft.rfft(ga, axis=2)  # (n, G, b//2+1)
    fb = jnp.fft.rfft(gb, axis=2)
    # acc[gi, gj] = sum_k conj(fa[k, gi]) * fb[k, gj]
    acc = jnp.einsum("kif,kjf->ijf", jnp.conj(fa), fb)
    return jnp.fft.irfft(acc, n=block, axis=2) / norm


def r_sum_grouped_ref(za, zb, block, q, norm):
    """Eq. (13): diagonal blocks skip their zeroth (trace) component,
    off-diagonal blocks keep all components."""
    sv = sumvec_grouped_fft_ref(za, zb, block, norm)  # (G, G, b)
    groups = sv.shape[0]
    absq = jnp.abs(sv) if q == 1 else sv**2
    # mask[gi, gj, c] = 0 iff gi == gj and c == 0
    eye = jnp.eye(groups, dtype=sv.dtype)
    comp0 = jnp.zeros(sv.shape[2], dtype=sv.dtype).at[0].set(1.0)
    mask = 1.0 - eye[:, :, None] * comp0[None, None, :]
    return jnp.sum(absq * mask)


def offdiag_sq_ref(m):
    """Same as r_off_ref — named for symmetry with the Pallas kernel."""
    return r_off_ref(m)


def diag_invariance_ref(za, zb, norm):
    """First term of Eq. (1) computed in O(nd): sum_i (1 - C_ii)^2 where
    C_ii = sum_k za[k,i] zb[k,i] / norm."""
    diag = jnp.sum(za * zb, axis=0) / norm
    return jnp.sum((1.0 - diag) ** 2)
