"""L1 Pallas kernels for the FFT decorrelation regularizer.

TPU-shaped thinking (see DESIGN.md §Hardware-Adaptation): the paper's CUDA
implementation leans on cuFFT plus pointwise torch ops. On TPU the FFT
itself maps to the XLA ``fft`` op; what deserves a hand-written kernel is

* ``spectral_reduce``          — the O(n·d) conj-multiply + batch-reduction
  between the forward and inverse FFTs of Eq. (12). VPU-shaped elementwise
  work, tiled so each (n × block_f) tile of the four real/imag planes sits
  in VMEM (TPU has no complex registers, so spectra travel as separate
  real/imag f32 arrays).
* ``grouped_spectral_reduce``  — the same reduction with a leading group
  axis for the R_sum^(b) regularizer of Eq. (13); the d/b groups become a
  grid dimension.
* ``crosscorr``                — the baseline's Z_aᵀ·Z_b matmul, MXU-tiled
  (128×128 output blocks, accumulated over batch tiles). This is the
  O(n·d²) contender the paper's Fig. 2 compares against.
* ``offdiag_sq``               — R_off's masked reduction over the d×d
  matrix, accumulated across grid steps into a scalar.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is both the correctness path and the
form that lowers into the AOT HLO artifacts. Block shapes are still chosen
as if for real VMEM (defaults keep every kernel under ~4 MiB of VMEM); the
structural analysis lives in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-conscious defaults (f32 elements).
DEFAULT_BLOCK_F = 512  # frequency-bin tile for the spectral reduction
DEFAULT_BLOCK_M = 128  # MXU-aligned output tile (rows)
DEFAULT_BLOCK_N = 128  # MXU-aligned output tile (cols)
DEFAULT_BLOCK_K = 128  # batch accumulation tile


def _ceil_div(a, b):
    return -(-a // b)


def _pad_axis(x, axis, multiple):
    """Zero-pad ``axis`` of ``x`` up to a multiple of ``multiple``."""
    size = x.shape[axis]
    target = _ceil_div(size, multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------
# spectral_reduce: acc[f] = sum_k conj(fa[k, f]) * fb[k, f]
# ---------------------------------------------------------------------------


def _spectral_reduce_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    """One frequency tile: complex conj-multiply, reduce over the batch.

    conj(a) * b = (ar·br + ai·bi) + i·(ar·bi − ai·br)
    """
    ar = ar_ref[...]
    ai = ai_ref[...]
    br = br_ref[...]
    bi = bi_ref[...]
    or_ref[...] = jnp.sum(ar * br + ai * bi, axis=0)
    oi_ref[...] = jnp.sum(ar * bi - ai * br, axis=0)


def _spectral_reduce_raw(fa_re, fa_im, fb_re, fb_im, block_f):
    """Unwrapped Pallas call (forward only)."""
    n, f = fa_re.shape
    bf = min(block_f, f)
    inputs = [_pad_axis(x, 1, bf) for x in (fa_re, fa_im, fb_re, fb_im)]
    fp = inputs[0].shape[1]
    grid = (fp // bf,)
    in_spec = pl.BlockSpec((n, bf), lambda i: (0, i))
    out_spec = pl.BlockSpec((bf,), lambda i: (i,))
    acc_re, acc_im = pl.pallas_call(
        _spectral_reduce_kernel,
        grid=grid,
        in_specs=[in_spec] * 4,
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((fp,), fa_re.dtype),
            jax.ShapeDtypeStruct((fp,), fa_re.dtype),
        ],
        interpret=True,
    )(*inputs)
    return acc_re[:f], acc_im[:f]


@functools.lru_cache(maxsize=None)
def _spectral_reduce_vjp(block_f):
    """custom_vjp wrapper per block size.

    Pallas kernels that accumulate across grid steps are not
    auto-differentiable; the reduction is bilinear, so the adjoints are
    closed-form pointwise products (which XLA fuses on the backward pass —
    matching the paper's observation that backward cost tracks forward
    cost through the loss node).
    """

    @jax.custom_vjp
    def f(ar, ai, br, bi):
        return _spectral_reduce_raw(ar, ai, br, bi, block_f)

    def fwd(ar, ai, br, bi):
        return f(ar, ai, br, bi), (ar, ai, br, bi)

    def bwd(res, g):
        ar, ai, br, bi = res
        gr, gi = g  # cotangents of (acc_re, acc_im), shape (F,)
        gr = gr[None, :]
        gi = gi[None, :]
        d_ar = br * gr + bi * gi
        d_ai = bi * gr - br * gi
        d_br = ar * gr - ai * gi
        d_bi = ai * gr + ar * gi
        return d_ar, d_ai, d_br, d_bi

    f.defvjp(fwd, bwd)
    return f


@functools.partial(jax.jit, static_argnames=("block_f", "use_pallas"))
def spectral_reduce(fa_re, fa_im, fb_re, fb_im, *, block_f=DEFAULT_BLOCK_F, use_pallas=True):
    """Batch-reduced complex conjugate product, the hot loop of Eq. (12).

    Args:
      fa_re, fa_im: real/imag planes of rfft(A), shape (n, F).
      fb_re, fb_im: real/imag planes of rfft(B), shape (n, F).
      block_f: frequency tile width (VMEM sizing knob).
      use_pallas: fall back to pure jnp when False (oracle path).

    Returns:
      (acc_re, acc_im), each of shape (F,): sum_k conj(fa_k) ∘ fb_k.
    """
    if not use_pallas:
        acc_re = jnp.sum(fa_re * fb_re + fa_im * fb_im, axis=0)
        acc_im = jnp.sum(fa_re * fb_im - fa_im * fb_re, axis=0)
        return acc_re, acc_im
    return _spectral_reduce_vjp(block_f)(fa_re, fa_im, fb_re, fb_im)


def sumvec_pallas(za, zb, norm, *, block_f=DEFAULT_BLOCK_F, use_pallas=True):
    """Full Eq. (12) pipeline: rfft → Pallas spectral reduction → irfft.

    The FFTs lower to the XLA ``fft`` op (vendor FFT on TPU, DUCC on CPU);
    the reduction between them is the Pallas kernel.
    """
    d = za.shape[1]
    fa = jnp.fft.rfft(za, axis=1)
    fb = jnp.fft.rfft(zb, axis=1)
    acc_re, acc_im = spectral_reduce(
        jnp.real(fa), jnp.imag(fa), jnp.real(fb), jnp.imag(fb),
        block_f=block_f, use_pallas=use_pallas,
    )
    acc = jax.lax.complex(acc_re, acc_im)
    return jnp.fft.irfft(acc, n=d, axis=0) / norm


# ---------------------------------------------------------------------------
# grouped_spectral_reduce: acc[gi, gj, f] = sum_k conj(fa[k, gi, f]) fb[k, gj, f]
# ---------------------------------------------------------------------------


def _grouped_spectral_reduce_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    """One (gi, gj) block pair: conj-multiply, reduce over batch axis 0.

    Block shapes: a* (n, 1, F), b* (n, 1, F), o* (1, 1, F).
    """
    ar = ar_ref[...]
    ai = ai_ref[...]
    br = br_ref[...]
    bi = bi_ref[...]
    or_ref[...] = jnp.sum(ar * br + ai * bi, axis=0)[None, ...]
    oi_ref[...] = jnp.sum(ar * bi - ai * br, axis=0)[None, ...]


def _grouped_spectral_reduce_raw(fa_re, fa_im, fb_re, fb_im):
    n, g, f = fa_re.shape
    grid = (g, g)
    a_spec = pl.BlockSpec((n, 1, f), lambda gi, gj: (0, gi, 0))
    b_spec = pl.BlockSpec((n, 1, f), lambda gi, gj: (0, gj, 0))
    o_spec = pl.BlockSpec((1, 1, f), lambda gi, gj: (gi, gj, 0))
    acc_re, acc_im = pl.pallas_call(
        _grouped_spectral_reduce_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[
            jax.ShapeDtypeStruct((g, g, f), fa_re.dtype),
            jax.ShapeDtypeStruct((g, g, f), fa_re.dtype),
        ],
        interpret=True,
    )(fa_re, fa_im, fb_re, fb_im)
    return acc_re, acc_im


@jax.custom_vjp
def _grouped_spectral_reduce_vjp(fa_re, fa_im, fb_re, fb_im):
    return _grouped_spectral_reduce_raw(fa_re, fa_im, fb_re, fb_im)


def _grouped_fwd(ar, ai, br, bi):
    return _grouped_spectral_reduce_vjp(ar, ai, br, bi), (ar, ai, br, bi)


def _grouped_bwd(res, g):
    # or[i,j,f] = Σ_k ar[k,i,f]·br[k,j,f] + ai[k,i,f]·bi[k,j,f]
    # oi[i,j,f] = Σ_k ar[k,i,f]·bi[k,j,f] − ai[k,i,f]·br[k,j,f]
    ar, ai, br, bi = res
    gr, gi = g  # (G, G, F)
    d_ar = jnp.einsum("kjf,ijf->kif", br, gr) + jnp.einsum("kjf,ijf->kif", bi, gi)
    d_ai = jnp.einsum("kjf,ijf->kif", bi, gr) - jnp.einsum("kjf,ijf->kif", br, gi)
    d_br = jnp.einsum("kif,ijf->kjf", ar, gr) - jnp.einsum("kif,ijf->kjf", ai, gi)
    d_bi = jnp.einsum("kif,ijf->kjf", ai, gr) + jnp.einsum("kif,ijf->kjf", ar, gi)
    return d_ar, d_ai, d_br, d_bi


_grouped_spectral_reduce_vjp.defvjp(_grouped_fwd, _grouped_bwd)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def grouped_spectral_reduce(fa_re, fa_im, fb_re, fb_im, *, use_pallas=True):
    """Grouped conj-multiply-reduce for R_sum^(b) (Eq. 13).

    Args:
      fa_*: rfft planes of the grouped view A, shape (n, G, F).
      fb_*: rfft planes of the grouped view B, shape (n, G, F).

    Returns:
      (acc_re, acc_im), each (G, G, F): entry [gi, gj] is the spectral
      accumulator of block C_{gi,gj}.
    """
    if not use_pallas:
        acc_re = jnp.einsum("kif,kjf->ijf", fa_re, fb_re) + jnp.einsum(
            "kif,kjf->ijf", fa_im, fb_im
        )
        acc_im = jnp.einsum("kif,kjf->ijf", fa_re, fb_im) - jnp.einsum(
            "kif,kjf->ijf", fa_im, fb_re
        )
        return acc_re, acc_im
    return _grouped_spectral_reduce_vjp(fa_re, fa_im, fb_re, fb_im)


# ---------------------------------------------------------------------------
# crosscorr: C = za^T zb / norm — the baseline O(n d^2) path, MXU-tiled
# ---------------------------------------------------------------------------


def _crosscorr_kernel(a_ref, b_ref, o_ref):
    """One (bm × bn) output tile, accumulated over the batch grid axis.

    a block: (bk, bm); b block: (bk, bn); o block: (bm, bn), revisited
    across grid axis 2 (the batch/contraction axis).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _crosscorr_raw(za, zb, block_m, block_n, block_k):
    n, d = za.shape
    bm = min(block_m, d)
    bn = min(block_n, d)
    bk = min(block_k, n)
    za_p = _pad_axis(_pad_axis(za, 1, bm), 0, bk)
    zb_p = _pad_axis(_pad_axis(zb, 1, bn), 0, bk)
    npad, dpa = za_p.shape
    dpb = zb_p.shape[1]
    grid = (dpa // bm, dpb // bn, npad // bk)
    out = pl.pallas_call(
        _crosscorr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dpa, dpb), za.dtype),
        interpret=True,
    )(za_p, zb_p)
    return out[:d, :d]


@functools.lru_cache(maxsize=None)
def _crosscorr_vjp(block_m, block_n, block_k):
    """C = zaᵀ·zb is bilinear: the adjoints are two (n×d)·(d×d) matmuls."""

    @jax.custom_vjp
    def f(za, zb):
        return _crosscorr_raw(za, zb, block_m, block_n, block_k)

    def fwd(za, zb):
        return f(za, zb), (za, zb)

    def bwd(res, g):
        za, zb = res
        return zb @ g.T, za @ g

    f.defvjp(fwd, bwd)
    return f


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "use_pallas")
)
def crosscorr(
    za,
    zb,
    norm,
    *,
    block_m=DEFAULT_BLOCK_M,
    block_n=DEFAULT_BLOCK_N,
    block_k=DEFAULT_BLOCK_K,
    use_pallas=True,
):
    """Cross-correlation matrix C = zaᵀ·zb / norm (inputs standardized).

    The MXU-native formulation of the Barlow Twins / VICReg baseline: the
    (d × d) output is tiled into (block_m × block_n) MXU tiles, contracted
    over batch tiles of block_k rows.
    """
    if not use_pallas:
        return (za.T @ zb) / norm
    return _crosscorr_vjp(block_m, block_n, block_k)(za, zb) / norm


# ---------------------------------------------------------------------------
# offdiag_sq: R_off(M) = sum of squared off-diagonal elements
# ---------------------------------------------------------------------------


def _offdiag_sq_kernel(m_ref, o_ref, *, block_m, block_n):
    """Partial sum of squared off-diagonal entries of one (bm × bn) tile,
    accumulated into the (1, 1) scalar output across the whole grid."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    m = m_ref[...]
    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, m.shape, 0)
    cols = j * block_n + jax.lax.broadcasted_iota(jnp.int32, m.shape, 1)
    mask = (rows != cols).astype(m.dtype)
    o_ref[...] += jnp.sum((m * mask) ** 2)[None, None]


def _offdiag_sq_raw(m, block_m, block_n):
    d0, d1 = m.shape
    bm = min(block_m, d0)
    bn = min(block_n, d1)
    mp = _pad_axis(_pad_axis(m, 0, bm), 1, bn)
    grid = (mp.shape[0] // bm, mp.shape[1] // bn)
    kernel = functools.partial(_offdiag_sq_kernel, block_m=bm, block_n=bn)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), m.dtype),
        interpret=True,
    )(mp)
    return out[0, 0]


@functools.lru_cache(maxsize=None)
def _offdiag_sq_vjp(block_m, block_n):
    """∂/∂M Σ_{i≠j} M_ij² = 2·M ⊙ (1 − I)."""

    @jax.custom_vjp
    def f(m):
        return _offdiag_sq_raw(m, block_m, block_n)

    def fwd(m):
        return f(m), m

    def bwd(m, g):
        d = m.shape[0]
        mask = 1.0 - jnp.eye(d, dtype=m.dtype)
        return (2.0 * g * m * mask,)

    f.defvjp(fwd, bwd)
    return f


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "use_pallas"))
def offdiag_sq(
    m, *, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N, use_pallas=True
):
    """R_off (Eq. 2) as a masked tiled reduction over a d×d matrix."""
    if not use_pallas:
        d = m.shape[0]
        mask = 1.0 - jnp.eye(d, dtype=m.dtype)
        return jnp.sum((m * mask) ** 2)
    return _offdiag_sq_vjp(block_m, block_n)(m)
