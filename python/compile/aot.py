"""AOT lowering: JAX functions → HLO text artifacts + JSON manifests.

This is the only place Python touches the pipeline; it runs once at build
time (``make artifacts``). Each artifact is emitted as

* ``<name>.hlo.txt``        — HLO **text**. jax ≥ 0.5 serializes protos
  with 64-bit instruction ids that xla_extension 0.5.1 (the version behind
  the rust ``xla`` crate) rejects; the text parser reassigns ids, so text
  is the interchange format (see /opt/xla-example/README.md).
* ``<name>.manifest.json``  — ordered input/output tensor specs (name,
  shape, dtype) in jax's pytree flattening order, plus free-form metadata.
  The rust coordinator marshals host buffers purely from this manifest.

Artifact families
-----------------
* ``train_<variant>_<preset>``   — one optimizer step (fwd+bwd+update).
* ``embed_<preset>``             — backbone features (linear eval).
* ``project_<preset>``           — projected embeddings (Table-6 diag).
* ``loss_<variant>_d<d>_n<n>``   — loss-only forward on embeddings
  (Fig. 2 / Tab. 12 timing workloads).
* ``lossgrad_<variant>_d<d>_n<n>`` — loss + grads wrt embeddings
  (backward-pass timing, Tab. 12/13).

Usage: ``python -m compile.aot --out-dir ../artifacts [--force]``.
"""

import argparse
import dataclasses
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# ---------------------------------------------------------------------------
# Presets: CPU-scale stand-ins for the paper's configurations.
# ---------------------------------------------------------------------------

IMAGE_SHAPE = (32, 32, 3)


@dataclasses.dataclass(frozen=True)
class Preset:
    name: str
    model: M.ModelConfig
    batch: int


PRESETS = {
    # MLP backbone over flat 64-dim inputs: fast artifacts for tests.
    "tiny": Preset(
        "tiny",
        M.ModelConfig(
            backbone="mlp",
            mlp_hidden=(128,),
            repr_dim=64,
            proj_hidden=128,
            proj_layers=2,
            embed_dim=256,
        ),
        batch=32,
    ),
    # Small convnet, d=1024: integration-test scale.
    "small": Preset(
        "small",
        M.ModelConfig(
            backbone="convnet",
            widths=(16, 32, 64),
            repr_dim=128,
            proj_hidden=512,
            proj_layers=3,
            embed_dim=1024,
        ),
        batch=64,
    ),
    # The end-to-end training preset (~2.4 M params, d=2048): the CPU-scale
    # analogue of the paper's ResNet-18 / d=2048 ImageNet-100 setup.
    "e2e": Preset(
        "e2e",
        M.ModelConfig(
            backbone="convnet",
            widths=(32, 64, 128, 256),
            repr_dim=256,
            proj_hidden=1024,
            proj_layers=3,
            embed_dim=2048,
        ),
        batch=128,
    ),
}

TINY_INPUT = (64,)  # flat input shape for the mlp backbone


def input_shape(preset: Preset):
    return TINY_INPUT if preset.model.backbone == "mlp" else IMAGE_SHAPE


# Loss-variant table: name → LossConfig kwargs. Hyperparameters follow the
# paper's Tables 9/10 where applicable (q=2 for BT-style, q=1 for VIC-style).
#
# ``use_pallas`` note: standard artifacts lower the *native XLA* forms
# (fused dot / rfft+einsum). On the CPU PJRT testbed, interpret-mode Pallas
# grids lower to sequential HLO while-loops, which would slow BOTH the
# baseline (by ~40x) and the proposed loss — distorting every timing
# comparison. The Pallas kernels still ship in dedicated ``*_pl_*`` probe
# artifacts (emitted below) that the rust suite checks for numerical
# equality against the native forms, and on a real TPU they are the forms
# that tile VMEM/MXU (DESIGN.md §Hardware-Adaptation).
def normalize_variant(spec: str) -> str:
    """Normalize the rust ``api::LossSpec`` grammar to an artifact fragment.

    ``"bt_sum@b=64,q=1"`` → ``"bt_sum_g64_q1"``; plain fragments pass
    through unchanged (idempotent). Only the structural options (``b``,
    ``q``) participate in artifact names; execution knobs (``norm``,
    ``lambda``, ``threads``) are ignored here. Canonical suffix order is
    ``_g<block>`` then ``_q<q>`` (an existing ``_q`` suffix is lifted so
    a ``@b=`` option lands before it), and ``@`` options override
    fragment suffixes — both mirroring ``LossSpec::parse``. The ``_q``
    suffix is dropped at the family default (q=2 for bt, q=1 for vic).
    """
    spec = spec.strip().lower()
    base, _, opts = spec.partition("@")
    # Lift existing structural suffixes so options can override them and
    # the canonical _g-then-_q order is restored on re-append.
    q = None
    if base.endswith(("_q1", "_q2")):
        q = int(base[-1])
        base = base[:-3]
    block = None
    if "_g" in base:
        base, _, blk = base.rpartition("_g")
        block = int(blk)
    for kv in filter(None, (t.strip() for t in opts.split(","))):
        key, _, value = kv.partition("=")
        if key in ("b", "block"):
            block = int(value)
        elif key == "q":
            q = int(value)
        elif key not in ("norm", "lambda", "lam", "threads", "t"):
            # Mirror LossSpec::parse: reject typos instead of silently
            # building artifacts for a different loss.
            raise ValueError(
                f"unknown loss-spec option '{key}' in '{spec}' "
                "(valid: b, q, norm, lambda, threads)"
            )
    if block is not None:
        base += f"_g{block}"
    default_q = 1 if base.startswith("vic") else 2
    if q is not None and q != default_q:
        base += f"_q{q}"
    return base


def split_variants(arg: str):
    """Split a --variants list. Semicolons separate entries when present;
    with commas, a bare ``key=value`` token (no ``@``) is the continuation
    of the previous entry's option list, so a single spec-grammar entry
    like ``"bt_sum@b=64,q=1"`` stays whole. Mirrors the rust CLI's
    ``parse_variant_list``."""
    if ";" in arg:
        entries = [t for t in arg.split(";") if t.strip()]
    else:
        entries = []
        for tok in arg.split(","):
            if not tok.strip():
                continue
            if "=" in tok and "@" not in tok and entries:
                entries[-1] += "," + tok
            else:
                entries.append(tok)
    return [normalize_variant(v) for v in entries]


def variant_cfg(variant: str, d: int, use_pallas: bool = False) -> M.LossConfig:
    variant = normalize_variant(variant)
    block = 0
    q_override = None
    base = variant
    # "_q1"/"_q2" suffix overrides the norm exponent (App. E.1 / Tab. 11).
    if base.endswith(("_q1", "_q2")):
        q_override = int(base[-1])
        base = base[:-3]
    if "_g" in base:
        base, blk = base.rsplit("_g", 1)
        block = int(blk)
    table = {
        "bt_off": dict(variant="bt_off", q=2, lam=0.0051, scale=0.1),
        "bt_sum": dict(variant="bt_sum", q=2, lam=2.0**-10, scale=0.125),
        "vic_off": dict(variant="vic_off", q=2, alpha=25.0, mu=25.0, nu=1.0),
        "vic_sum": dict(variant="vic_sum", q=1, alpha=25.0, mu=25.0, nu=1.0, scale=0.25),
    }
    if base not in table:
        raise ValueError(f"unknown loss variant {variant}")
    kwargs = dict(table[base])
    kwargs["block"] = block
    kwargs["use_pallas"] = use_pallas
    if q_override is not None:
        kwargs["q"] = q_override
    return M.LossConfig(**kwargs)


OPT = M.OptConfig(optimizer="lars", momentum=0.9, weight_decay=1e-4)

VARIANTS = ["bt_off", "bt_sum", "bt_sum_g128", "vic_off", "vic_sum", "vic_sum_g128"]


# ---------------------------------------------------------------------------
# Lowering machinery
# ---------------------------------------------------------------------------


def _path_str(prefix: str, path) -> str:
    """'params' + (DictKey('backbone'), DictKey('conv0_w')) → 'params.backbone.conv0_w'."""
    parts = [prefix]
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def _specs(prefix: str, tree):
    """Flatten a pytree of arrays into ordered (name, shape, dtype) specs."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    specs = []
    for path, leaf in leaves:
        # leaf is a concrete array or a ShapeDtypeStruct — both carry
        # .shape/.dtype.
        dtype = {"float32": "f32", "int32": "i32"}[str(leaf.dtype)]
        specs.append(
            {
                "name": _path_str(prefix, path),
                "shape": list(leaf.shape),
                "dtype": dtype,
            }
        )
    return specs


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for stable
    multi-output decomposition on the rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir, name, fn, arg_trees, arg_names, out_names, meta, force=False):
    """Lower ``fn`` at the abstract shapes of ``arg_trees`` and write the
    artifact pair. Skips work when the manifest exists with the same
    content hash of the lowering config (incremental ``make artifacts``)."""
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{name}.manifest.json")

    in_specs = []
    for prefix, tree in zip(arg_names, arg_trees):
        in_specs.extend(_specs(prefix, tree))

    out_tree = jax.eval_shape(fn, *arg_trees)
    out_specs = []
    for prefix, tree in zip(out_names, out_tree if isinstance(out_tree, tuple) else (out_tree,)):
        out_specs.extend(_specs(prefix, tree))

    manifest = {
        "name": name,
        "inputs": in_specs,
        "outputs": out_specs,
        "meta": meta,
    }
    man_text = json.dumps(manifest, indent=1, sort_keys=True)
    config_hash = hashlib.sha256(man_text.encode()).hexdigest()[:16]

    if not force and os.path.exists(man_path) and os.path.exists(hlo_path):
        try:
            old = json.load(open(man_path))
            if old.get("meta", {}).get("config_hash") == config_hash:
                print(f"  [skip] {name} (unchanged)")
                return
        except (json.JSONDecodeError, OSError):
            pass

    lowered = jax.jit(fn).lower(*arg_trees)
    # jax dead-code-eliminates unused flattened inputs (e.g. projector
    # params in the embed artifact); the HLO entry signature only has the
    # *kept* ones. The manifest must describe exactly that signature —
    # the rust side marshals buffers positionally from it.
    kept = getattr(lowered._lowering, "compile_args", {}).get("kept_var_idx")
    if kept is not None:
        kept = sorted(kept)
        in_specs = [in_specs[i] for i in kept]
        manifest["inputs"] = in_specs
    manifest["meta"] = dict(meta, config_hash=config_hash)
    man_text = json.dumps(manifest, indent=1, sort_keys=True)
    text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    with open(man_path, "w") as f:
        f.write(man_text)
    print(f"  [emit] {name}: {len(in_specs)} in / {len(out_specs)} out, "
          f"{len(text) / 1e6:.2f} MB hlo")


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


def abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), jnp.asarray(x).dtype), tree
    )


def build_train(out_dir, preset: Preset, variant: str, force):
    mc = preset.model
    lc = variant_cfg(variant, mc.embed_dim)
    step = M.make_train_step(mc, lc, OPT)
    params = M.init_params(jax.random.PRNGKey(0), mc, input_shape(preset))
    opt_state = M.init_opt_state(params)
    n = preset.batch
    x_shape = (n, *input_shape(preset))
    xa = jnp.zeros(x_shape, jnp.float32)
    perm = jnp.arange(mc.embed_dim, dtype=jnp.int32)
    lr = jnp.zeros((), jnp.float32)
    meta = {
        "kind": "train_step",
        "preset": preset.name,
        "variant": variant,
        "d": mc.embed_dim,
        "n": n,
        "block": lc.block,
        "q": lc.q,
        "backbone": mc.backbone,
        "image": list(input_shape(preset)),
    }
    emit(
        out_dir,
        f"train_{variant}_{preset.name}",
        step,
        (abstract(params), abstract(opt_state), xa, xa, perm, lr),
        ["params", "opt_state", "xa", "xb", "perm", "lr"],
        ["params", "opt_state", "loss", "inv", "reg"],
        meta,
        force,
    )


def write_checkpoint(path, named_tensors):
    """decorr checkpoint format (shared with rust/src/coordinator/checkpoint.rs):

    line 1: ``DECORRCKPT1``
    line 2: JSON header ``{"tensors": [{"name", "shape", "dtype"}, ...]}``
    rest:   concatenated little-endian payloads in header order.
    """
    header = {
        "tensors": [
            {"name": n, "shape": list(np.shape(t)), "dtype": "f32"}
            for n, t in named_tensors
        ]
    }
    with open(path, "wb") as f:
        f.write(b"DECORRCKPT1\n")
        f.write((json.dumps(header, sort_keys=True) + "\n").encode())
        for _, t in named_tensors:
            f.write(np.asarray(t, dtype="<f4").tobytes())


def build_init(out_dir, preset: Preset, seed, force):
    """Emit the initial parameter values (jax He init) as a checkpoint the
    rust trainer loads; parameter names match the train manifest's
    ``params.*`` inputs."""
    path = os.path.join(out_dir, f"init_{preset.name}.ckpt")
    if not force and os.path.exists(path):
        print(f"  [skip] init_{preset.name}.ckpt (exists)")
        return
    mc = preset.model
    params = M.init_params(jax.random.PRNGKey(seed), mc, input_shape(preset))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    named = [(_path_str("params", p), np.asarray(v)) for p, v in leaves]
    write_checkpoint(path, named)
    total = sum(int(np.prod(np.shape(v))) for _, v in named)
    print(f"  [emit] init_{preset.name}.ckpt: {len(named)} tensors, {total / 1e6:.2f} M params")


def build_embed(out_dir, preset: Preset, force):
    mc = preset.model
    fn = M.make_embed(mc)
    params = M.init_params(jax.random.PRNGKey(0), mc, input_shape(preset))
    x = jnp.zeros((preset.batch, *input_shape(preset)), jnp.float32)
    meta = {
        "kind": "embed",
        "preset": preset.name,
        "repr_dim": mc.repr_dim,
        "n": preset.batch,
        "image": list(input_shape(preset)),
    }
    emit(
        out_dir,
        f"embed_{preset.name}",
        fn,
        (abstract(params), x),
        ["params", "x"],
        ["repr"],
        meta,
        force,
    )


def build_project(out_dir, preset: Preset, force):
    mc = preset.model
    fn = M.make_project(mc)
    params = M.init_params(jax.random.PRNGKey(0), mc, input_shape(preset))
    x = jnp.zeros((preset.batch, *input_shape(preset)), jnp.float32)
    meta = {
        "kind": "project",
        "preset": preset.name,
        "d": mc.embed_dim,
        "n": preset.batch,
        "image": list(input_shape(preset)),
    }
    emit(
        out_dir,
        f"project_{preset.name}",
        fn,
        (abstract(params), x),
        ["params", "x"],
        ["z"],
        meta,
        force,
    )


def build_grad_step(out_dir, preset: Preset, variant: str, shards: int, force):
    """Per-shard gradient computation for the simulated-DDP coordinator
    (paper App. E.3): (params, xa, xb, perm) → (grads, loss, inv, reg).
    The shard batch is n/shards; the proposed losses need no cross-shard
    statistics (the paper's "no collective operations" property), so
    shard gradients simply average."""
    mc = preset.model
    lc = variant_cfg(variant, mc.embed_dim)
    n = preset.batch // shards
    assert n * shards == preset.batch, "shards must divide the preset batch"

    def grad_fn(params, xa, xb, perm):
        def objective(p):
            za = M.embed(p, xa, mc)
            zb = M.embed(p, xb, mc)
            return M.loss_fn(za, zb, perm, lc)

        (loss, metrics), grads = jax.value_and_grad(objective, has_aux=True)(params)
        return grads, loss, metrics["inv"], metrics["reg"]

    params = M.init_params(jax.random.PRNGKey(0), mc, input_shape(preset))
    xa = jnp.zeros((n, *input_shape(preset)), jnp.float32)
    perm = jnp.arange(mc.embed_dim, dtype=jnp.int32)
    meta = {
        "kind": "grad_step",
        "preset": preset.name,
        "variant": variant,
        "d": mc.embed_dim,
        "n": n,
        "shards": shards,
        "image": list(input_shape(preset)),
    }
    emit(
        out_dir,
        f"grad_{variant}_{preset.name}_s{shards}",
        grad_fn,
        (abstract(params), xa, xa, perm),
        ["params", "xa", "xb", "perm"],
        ["grads", "loss", "inv", "reg"],
        meta,
        force,
    )


def build_train_multi(out_dir, preset: Preset, variant: str, unroll: int, force):
    """Multi-step train artifact (§Perf L2/L3): `unroll` optimizer steps
    fused into one executable via lax.scan over stacked batches. Amortizes
    the per-dispatch costs of the single-step path (host↔device literal
    copies of the full parameter set, tuple decomposition, PJRT dispatch)
    by the unroll factor — the dominant overhead when the model is small
    and the loss node is the workload."""
    mc = preset.model
    lc = variant_cfg(variant, mc.embed_dim)
    n = preset.batch

    def multi_step(params, opt_state, xas, xbs, perms, lrs):
        def body(carry, inputs):
            p, o = carry
            xa, xb, perm, lr = inputs

            def objective(pp):
                za = M.embed(pp, xa, mc)
                zb = M.embed(pp, xb, mc)
                return M.loss_fn(za, zb, perm, lc)

            (loss, _metrics), grads = jax.value_and_grad(objective, has_aux=True)(p)
            p2, o2 = M.opt_update(p, grads, o, lr, OPT)
            return (p2, o2), loss

        (p_final, o_final), losses = jax.lax.scan(
            body, (params, opt_state), (xas, xbs, perms, lrs)
        )
        return p_final, o_final, losses

    params = M.init_params(jax.random.PRNGKey(0), mc, input_shape(preset))
    opt_state = M.init_opt_state(params)
    xas = jnp.zeros((unroll, n, *input_shape(preset)), jnp.float32)
    perms = jnp.zeros((unroll, mc.embed_dim), jnp.int32)
    lrs = jnp.zeros((unroll,), jnp.float32)
    meta = {
        "kind": "train_multi",
        "preset": preset.name,
        "variant": variant,
        "d": mc.embed_dim,
        "n": n,
        "unroll": unroll,
        "image": list(input_shape(preset)),
    }
    emit(
        out_dir,
        f"trainmulti_{variant}_{preset.name}_k{unroll}",
        multi_step,
        (abstract(params), abstract(opt_state), xas, xas, perms, lrs),
        ["params", "opt_state", "xas", "xbs", "perms", "lrs"],
        ["params", "opt_state", "losses"],
        meta,
        force,
    )


def build_apply(out_dir, preset: Preset, force):
    """Optimizer application for the DDP coordinator:
    (params, opt_state, grads, lr) → (params', opt_state')."""
    mc = preset.model

    def apply_fn(params, opt_state, grads, lr):
        return M.opt_update(params, grads, opt_state, lr, OPT)

    params = M.init_params(jax.random.PRNGKey(0), mc, input_shape(preset))
    opt_state = M.init_opt_state(params)
    lr = jnp.zeros((), jnp.float32)
    meta = {"kind": "apply", "preset": preset.name}
    emit(
        out_dir,
        f"apply_{preset.name}",
        apply_fn,
        (abstract(params), abstract(opt_state), abstract(params), lr),
        ["params", "opt_state", "grads", "lr"],
        ["params", "opt_state"],
        meta,
        force,
    )


def build_loss_only(out_dir, variant: str, d: int, n: int, force, with_grad=False, pallas=False):
    lc = variant_cfg(variant, d, use_pallas=pallas)
    fn = M.make_loss_grad(lc) if with_grad else M.make_loss_only(lc)
    za = jnp.zeros((n, d), jnp.float32)
    perm = jnp.arange(d, dtype=jnp.int32)
    kind = ("lossgrad" if with_grad else "loss") + ("_pl" if pallas else "")
    meta = {
        "kind": kind,
        "variant": variant,
        "d": d,
        "n": n,
        "block": lc.block,
        "q": lc.q,
        "pallas": pallas,
    }
    out_names = ["loss", "grad_za", "grad_zb"] if with_grad else ["loss"]
    emit(
        out_dir,
        f"{kind}_{variant}_d{d}_n{n}",
        fn,
        (za, za, perm),
        ["za", "zb", "perm"],
        out_names,
        meta,
        force,
    )


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small,e2e")
    ap.add_argument("--variants", default=",".join(VARIANTS))
    ap.add_argument(
        "--bench-dims",
        default="256,512,1024,2048,4096",
        help="embedding dims for the loss-only Fig. 2 sweep",
    )
    ap.add_argument("--bench-n", type=int, default=128)
    ap.add_argument(
        "--bench-variants",
        default="bt_off,bt_sum,bt_sum_g128,vic_off,vic_sum",
        help="variants included in the loss-only sweep",
    )
    ap.add_argument(
        "--fig3-blocks",
        default="8,32,128,512,2048",
        help="block sizes for the Fig. 3 grouping sweep (at d=2048)",
    )
    ap.add_argument("--skip-bench", action="store_true")
    ap.add_argument("--skip-train", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    presets = [PRESETS[p] for p in args.presets.split(",") if p]
    variants = split_variants(args.variants)

    if not args.skip_train:
        for preset in presets:
            print(f"preset {preset.name}:")
            build_init(args.out_dir, preset, seed=0, force=args.force)
            build_embed(args.out_dir, preset, args.force)
            build_project(args.out_dir, preset, args.force)
            for variant in variants:
                build_train(args.out_dir, preset, variant, args.force)

    if not args.skip_bench:
        print("bench sweep:")
        dims = [int(d) for d in args.bench_dims.split(",") if d]
        for variant in split_variants(args.bench_variants):
            for d in dims:
                build_loss_only(args.out_dir, variant, d, args.bench_n, args.force)
                build_loss_only(
                    args.out_dir, variant, d, args.bench_n, args.force, with_grad=True
                )
        # Fig. 3 block-size sweep: R_sum^(b) at fixed d across b values
        # (b == d is the ungrouped R_sum; b == 1 ≡ R_off is covered by the
        # bt_off artifact above).
        fig3_d = 2048
        for b in [int(x) for x in args.fig3_blocks.split(",") if x]:
            build_loss_only(args.out_dir, f"bt_sum_g{b}", fig3_d, args.bench_n, args.force)
            build_loss_only(
                args.out_dir, f"bt_sum_g{b}", fig3_d, args.bench_n, args.force,
                with_grad=True,
            )
        # Pallas-lowered probe artifacts: the L1 kernels compiled into HLO,
        # used by the rust suite for native-vs-Pallas numerical equality
        # and by the kernel-form ablation bench.
        for variant in ["bt_off", "bt_sum", "bt_sum_g128", "vic_sum"]:
            build_loss_only(
                args.out_dir, variant, 512, args.bench_n, args.force, pallas=True
            )

    if not args.skip_train:
        small = PRESETS["small"]
        # Simulated-DDP artifacts (App. E.3): per-shard grads + apply.
        build_apply(args.out_dir, small, args.force)
        for variant in ["bt_off", "bt_sum"]:
            for shards in [1, 2, 4]:
                build_grad_step(args.out_dir, small, variant, shards, args.force)
        # q-exponent ablation artifacts (App. E.1 / Tab. 11).
        for variant in ["bt_sum_q1", "vic_sum_q2", "bt_sum_g128_q1", "vic_sum_g128_q2"]:
            build_train(args.out_dir, small, variant, args.force)
        # Multi-step fused train artifacts (§Perf): scan-unrolled steps.
        for k in [4, 16]:
            build_train_multi(args.out_dir, PRESETS["tiny"], "bt_sum", k, args.force)

    print("done.")


if __name__ == "__main__":
    main()
