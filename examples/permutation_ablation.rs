//! Permutation ablation (paper Tables 5 and 6): trains the proposed
//! regularizer with and without per-batch feature permutation and reports
//! (a) linear-eval accuracy and per-10-step training time (Tab. 5 shape),
//! (b) the normalized R_off residual of the trained embeddings
//!     (Tab. 6 / Eqs. 16–17).
//!
//! The paper's claim under test: *without permutation the relaxed
//! regularizer is nearly blind — accuracy collapses and true decorrelation
//! (measured by R_off) stays poor; with permutation both recover.*
//!
//! Run with: `cargo run --release --offline --example permutation_ablation
//!            [--preset small --epochs 6 --family bt]`

use anyhow::Result;
use decorr::bench_harness::cmd::pretrain_and_eval;
use decorr::bench_harness::Table;
use decorr::config::{TrainConfig, Variant};
use decorr::coordinator::project_views;
use decorr::regularizer::kernel::normalized_residual;
use decorr::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let preset = args.str_or("preset", "small");
    let mut cfg0 = TrainConfig::preset(&preset)?;
    cfg0.epochs = args.get_or("epochs", cfg0.epochs)?;
    cfg0.steps_per_epoch = args.get_or("steps-per-epoch", cfg0.steps_per_epoch)?;
    cfg0.seed = args.get_or("seed", cfg0.seed)?;
    let family = args.str_or("family", "bt");
    let train_samples = args.get_or("train-samples", 1536usize)?;
    let test_samples = args.get_or("test-samples", 512usize)?;
    args.finish()?;

    let (flat, grouped) = if family == "vic" {
        (Variant::VicSum.spec(), Variant::VicSumG128.spec())
    } else {
        (Variant::BtSum.spec(), Variant::BtSumG128.spec())
    };
    // The Table-6 residual family (Eq. 16 vs 17) is spec-derived.
    let residual_family = flat.residual_family();

    let mut tab5 = Table::new(&["grouping", "permutation", "top-1 (%)", "s / 10 steps"]);
    let mut tab6 = Table::new(&["grouping", "permutation", "normalized residual"]);

    // One session threaded through the whole ablation: the eval and
    // projection artifacts compile once for all four runs.
    let mut session = None;
    for (variant, grouping) in [(flat, "no"), (grouped, "b=128")] {
        for permute in [false, true] {
            let mut cfg = cfg0.clone();
            cfg.spec = variant;
            cfg.permute = permute;
            println!("== {} permutation={} ==", variant.display_name(), permute);
            let out = pretrain_and_eval(cfg.clone(), train_samples, test_samples, 150, session)?;
            let s_per_10 =
                out.train_secs / (cfg.total_steps() as f64) * 10.0;
            tab5.row(vec![
                grouping.to_string(),
                if permute { "yes" } else { "no" }.to_string(),
                format!("{:.2}", out.top1),
                format!("{s_per_10:.2}"),
            ]);

            // Table-6 residual on freshly projected twin views, through
            // the DecorrelationKernel trait.
            let (za, zb) =
                project_views(&out.session, &cfg.preset, &out.snapshot, out.adapter, cfg.seed, 4)?;
            let residual = normalized_residual(residual_family, &za, &zb);
            session = Some(out.session);
            tab6.row(vec![
                grouping.to_string(),
                if permute { "yes" } else { "no" }.to_string(),
                format!("{residual:.5}"),
            ]);
        }
    }

    println!("\nTable 5 analogue ({family}-style, preset {preset}):");
    tab5.print();
    println!("\nTable 6 analogue (normalized R_off residual of trained embeddings):");
    tab6.print();
    println!(
        "\n(paper shape: permutation=no rows lose many accuracy points and keep a\n\
         much larger residual; permutation=yes restores both at negligible time cost)"
    );
    Ok(())
}
