//! End-to-end driver (DESIGN.md §3 "E2E"): full SSL pretraining of the
//! e2e preset (~3.9 M-parameter convnet + projector, d = 2048) with the
//! proposed FFT regularizer on ShapeWorld, followed by the linear
//! evaluation protocol — all three layers composing: rust coordinator →
//! AOT HLO (jax model) → spectral regularizer (validated against the
//! Pallas kernels).
//!
//! Run with:
//!   cargo run --release --offline --example train_ssl_e2e
//! Flags (optional): --epochs N --steps-per-epoch K --variant bt_sum
//!                   --preset e2e --out-dir runs/e2e --resume path.ckpt
//!
//! The loss curve lands in <out-dir>/metrics.jsonl; the run summary is
//! recorded in EXPERIMENTS.md.

use anyhow::Result;
use decorr::api::train::DriverBuilder;
use decorr::api::LossSpec;
use decorr::config::TrainConfig;
use decorr::coordinator::linear_eval;
use decorr::data::synth::{ShapeWorld, ShapeWorldConfig, Vocab};
use decorr::util::cli::Args;
use decorr::util::timer::human_duration;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let mut cfg = TrainConfig::preset_e2e();
    cfg.spec = LossSpec::parse(&args.str_or("variant", "bt_sum"))?;
    let preset_flag = args.str_or("preset", &cfg.preset.clone());
    cfg.preset = preset_flag;
    cfg.epochs = args.get_or("epochs", cfg.epochs)?;
    cfg.steps_per_epoch = args.get_or("steps-per-epoch", cfg.steps_per_epoch)?;
    cfg.out_dir = args.str_or("out-dir", "runs/e2e");
    cfg.lr = args.get_or("lr", cfg.lr)?;
    let train_samples = args.get_or("train-samples", 3072usize)?;
    let test_samples = args.get_or("test-samples", 768usize)?;
    let resume = args.flag("resume");
    args.finish()?;

    println!(
        "=== end-to-end SSL pretraining: {} on preset {} ({} epochs x {} steps) ===",
        cfg.spec, cfg.preset, cfg.epochs, cfg.steps_per_epoch
    );
    let seed = cfg.seed;
    let preset = cfg.preset.clone();
    let out_dir = cfg.out_dir.clone();
    let mut builder = DriverBuilder::new(cfg);
    if let Some(path) = &resume {
        println!("resuming parameters from {path}");
        builder = builder.resume_from(path.clone());
    }
    let mut trainer = builder.build_trainer()?;
    println!(
        "batch size {} | embed dim {}",
        trainer.batch_size()?,
        trainer.embed_dim()
    );
    let report = trainer.run()?;
    println!(
        "\npretraining done: {} steps in {} ({:.2} steps/s); loss {:.4} -> {:.4}",
        report.steps,
        human_duration(report.wall_seconds),
        report.steps_per_sec,
        report.initial_loss,
        report.final_loss
    );

    // Loss curve summary (decile means) for the record.
    let hist = trainer.metrics().history();
    let decile = (hist.len() / 10).max(1);
    println!("\nloss curve (decile means):");
    for c in hist.chunks(decile) {
        let mean: f32 = c.iter().map(|m| m.loss).sum::<f32>() / c.len() as f32;
        println!(
            "  steps {:>4}-{:<4} mean loss {:.4}",
            c[0].step,
            c[c.len() - 1].step,
            mean
        );
    }

    // Full run state (format v2): a later `--resume` continues momentum
    // and the LR-schedule position, not just the parameters.
    let snapshot = trainer.snapshot_state()?;
    std::fs::create_dir_all(&out_dir)?;
    let ckpt = format!("{out_dir}/final.ckpt");
    snapshot.save(&ckpt)?;
    println!(
        "checkpoint saved to {ckpt} (v2: {} params + {} opt-state elems @ step {})",
        snapshot.num_params(),
        snapshot.num_opt_params(),
        snapshot.step
    );

    // --- linear evaluation (frozen backbone) -----------------------------
    println!("\n=== linear evaluation (ShapeWorld-A) ===");
    let dataset = ShapeWorld::new(ShapeWorldConfig {
        seed,
        ..Default::default()
    });
    let eval = linear_eval(
        trainer.session(),
        &preset,
        &snapshot,
        &dataset,
        trainer.input_adapter(),
        train_samples,
        test_samples,
        200,
    )?;
    println!(
        "top-1 {:.2}% (train split {:.2}%; chance {:.2}%)",
        eval.top1 * 100.0,
        eval.train_top1 * 100.0,
        100.0 / dataset.num_classes() as f32
    );
    println!(
        "feature decorrelation residual {:.5} (Eq. 16 via DecorrelationKernel)",
        eval.feature_residual
    );

    // --- transfer probe (ShapeWorld-B, paper Tab. 3 analogue) ------------
    println!("\n=== transfer probe (ShapeWorld-B) ===");
    let transfer_ds = ShapeWorld::new(ShapeWorldConfig {
        seed: seed + 1,
        vocab: Vocab::B,
        ..Default::default()
    });
    let transfer = linear_eval(
        trainer.session(),
        &preset,
        &snapshot,
        &transfer_ds,
        trainer.input_adapter(),
        train_samples / 2,
        test_samples / 2,
        200,
    )?;
    println!(
        "transfer top-1 {:.2}% (chance {:.2}%)",
        transfer.top1 * 100.0,
        100.0 / transfer_ds.num_classes() as f32
    );
    println!("\ne2e driver OK");
    Ok(())
}
