//! Parallel spec-grid sweeps through the work-stealing `SweepScheduler`.
//!
//! 1. Host mode (no artifacts, no PJRT): expand a `(b, q)` grid and
//!    measure every spec's host `LossExecutor` across worker threads,
//!    then verify the scheduler's determinism contract — per-spec values
//!    are bit-identical no matter how many workers ran the grid.
//! 2. Train mode (requires `make artifacts`): the same grid surface over
//!    `TrainDriver`s, each worker owning one per-thread `Session` arm of
//!    a single shared session core, with the cross-arm compile/hit
//!    stats printed at the end.
//!
//! Run with: `cargo run --release --offline --example parallel_sweep`

use anyhow::Result;
use decorr::api::train::{SweepMode, SweepPlan, SweepScheduler};
use decorr::config::TrainConfig;

fn main() -> Result<()> {
    // --- 1. Host-mode grid across workers -------------------------------
    let grid = "bt_sum@b={64,128},q={1,2};vic_sum";
    let plan = SweepPlan::parse(grid)?;
    let mode = SweepMode::Host {
        d: 256,
        n: 64,
        budget: 0.05,
    };
    println!("host grid '{grid}' -> {} specs", plan.len());
    let serial = SweepScheduler::new(plan.clone(), mode.clone()).workers(1).run()?;
    let workers = std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(2);
    let parallel = SweepScheduler::new(plan, mode).workers(workers).run()?;
    println!(
        "serial {:.2}s vs {} workers {:.2}s ({:.2}x)",
        serial.wall_seconds,
        parallel.workers,
        parallel.wall_seconds,
        serial.wall_seconds / parallel.wall_seconds
    );
    parallel.table().print();
    for (s, p) in serial.results.iter().zip(&parallel.results) {
        assert_eq!(
            s.report.final_loss.to_bits(),
            p.report.final_loss.to_bits(),
            "determinism contract broken for {}",
            s.report.spec
        );
    }
    println!("per-spec values bit-identical across worker counts ✓");

    // --- 2. Train-mode grid over per-thread session arms ----------------
    let present: Vec<&str> = ["bt_sum", "bt_off", "vic_sum"]
        .into_iter()
        .filter(|v| {
            std::path::Path::new(&format!("artifacts/train_{v}_tiny.manifest.json")).exists()
        })
        .collect();
    if present.is_empty() {
        println!("\n(skipping train-mode sweep: run `make artifacts` first)");
        return Ok(());
    }
    let mut base = TrainConfig::preset_tiny();
    base.epochs = 1;
    base.steps_per_epoch = 4;
    base.out_dir = String::new();
    base.log_every = usize::MAX;
    let plan = SweepPlan::parse(&present.join(";"))?;
    let outcome = SweepScheduler::new(
        plan,
        SweepMode::Train { base, shards: 0 },
    )
    .workers(2)
    .run()?;
    println!("\ntrain-mode sweep ({} workers):", outcome.workers);
    outcome.table().print();
    if let Some(stats) = &outcome.session_stats {
        println!(
            "session arms {} | compiles {} ({:.0} ms) | hits {} | sources read {}",
            stats.arms, stats.compiles, stats.compile_ms, stats.hits, stats.source_reads
        );
    }
    Ok(())
}
