//! Scaling curves (paper Fig. 2): loss-node forward / forward+backward
//! time and the loss-node memory model versus embedding dimension d, for
//! the baselines (R_off) and the proposed FFT regularizers (R_sum), plus
//! the b=128 grouped variant.
//!
//! Also writes a CSV (`runs/fig2.csv`) for plotting.
//!
//! Run with: `cargo run --release --offline --example scaling_curves
//!            [--dims 256,512,1024,2048,4096] [--budget 0.4]`

use anyhow::Result;
use decorr::bench_harness::{bench_for, loss_node_bytes, LossWorkload, Table};
use decorr::runtime::Session;
use decorr::util::cli::Args;
use std::io::Write;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let dims: Vec<usize> = args.list_or("dims", &[256usize, 512, 1024, 2048, 4096])?;
    let n = args.get_or("n", 128usize)?;
    let budget = args.get_or("budget", 0.4f64)?;
    let csv_path = args.str_or("csv", "runs/fig2.csv");
    args.finish()?;

    let variants = ["bt_off", "bt_sum", "bt_sum_g128", "vic_off", "vic_sum"];
    let session = Session::open("artifacts")?;
    std::fs::create_dir_all(std::path::Path::new(&csv_path).parent().unwrap())?;
    let mut csv = std::fs::File::create(&csv_path)?;
    writeln!(csv, "variant,d,fwd_ms,fwdbwd_ms,loss_node_mb")?;

    let mut table = Table::new(&["variant", "d", "fwd (ms)", "fwd+bwd (ms)", "loss-node MB"]);
    for v in &variants {
        for &d in &dims {
            let fwd = LossWorkload::load(&session, v, d, n, false)?;
            let f = bench_for(budget, 2, || fwd.run().unwrap());
            let bwd = LossWorkload::load(&session, v, d, n, true)?;
            let b = bench_for(budget, 2, || bwd.run().unwrap());
            let mb = loss_node_bytes(v, n, d) as f64 / 1e6;
            writeln!(
                csv,
                "{v},{d},{:.4},{:.4},{:.3}",
                f.median_ms(),
                b.median_ms(),
                mb
            )?;
            table.row(vec![
                v.to_string(),
                format!("{d}"),
                format!("{:.2}", f.median_ms()),
                format!("{:.2}", b.median_ms()),
                format!("{mb:.1}"),
            ]);
        }
    }
    println!("\nFig. 2 analogue (n = {n}); CSV written to {csv_path}:");
    table.print();

    // Speedup summary at the largest d (the paper's headline numbers).
    let d = *dims.last().unwrap();
    let t = |v: &str| -> Result<f64> {
        let w = LossWorkload::load(&session, v, d, n, false)?;
        Ok(bench_for(budget, 2, || w.run().unwrap()).median)
    };
    println!(
        "\nat d={d}: proposed vs Barlow Twins {:.1}x, proposed vs VICReg {:.1}x (fwd loss)",
        t("bt_off")? / t("bt_sum")?,
        t("vic_off")? / t("vic_sum")?
    );
    Ok(())
}
