//! Quickstart: the five-minute tour of the decorr public API.
//!
//! 1. Open a runtime `Session` (the process-wide artifact cache over the
//!    PJRT engine) and load an AOT loss artifact — loading it again is a
//!    cache hit, not a second O(seconds) compile.
//! 2. Compute the proposed FFT regularizer on-device and validate it
//!    against the pure-rust host implementation (paper Eq. 12), then
//!    against the planned `DecorrelationKernel` host path.
//! 3. Run a few SSL pretraining steps on the tiny preset.
//!
//! Run with: `cargo run --release --offline --example quickstart`
//! (requires `make artifacts`).

use anyhow::Result;
use decorr::api::train::DriverBuilder;
use decorr::api::{LossExecutor, LossSpec};
use decorr::config::TrainConfig;
use decorr::coordinator::trainer::{literal_f32, literal_i32, scalar};
use decorr::regularizer::kernel::{DecorrelationKernel, FftSumvecKernel};
use decorr::regularizer::{self, Q};
use decorr::runtime::Session;
use decorr::util::rng::Rng;
use decorr::util::tensor::Tensor;

fn main() -> Result<()> {
    // --- 1. Session + artifact ------------------------------------------
    let session = Session::open("artifacts")?;
    println!("engine: platform={}", session.engine().platform());
    let loss = session.load("loss_bt_sum_d256_n128")?;
    println!(
        "loaded '{}': {} inputs, {} outputs",
        loss.manifest().name,
        loss.manifest().inputs.len(),
        loss.manifest().outputs.len()
    );
    // A second load of the same shape is a cache hit on the same
    // executable — the device-side analogue of reusing an FftPlan.
    let again = session.load("loss_bt_sum_d256_n128")?;
    assert!(std::sync::Arc::ptr_eq(&loss, &again));
    let stats = session.stats();
    println!(
        "session: {} loads, {} compiles ({:.0} ms compiling), {} hits",
        stats.loads, stats.compiles, stats.compile_ms, stats.hits
    );

    // --- 2. Device loss vs host reference -------------------------------
    let (n, d) = (128, 256);
    let mut rng = Rng::new(1);
    let za = Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.gaussian()).collect());
    let zb = Tensor::from_vec(&[n, d], (0..n * d).map(|_| rng.gaussian()).collect());
    let perm: Vec<u32> = (0..d as u32).collect();
    let out = loss.execute_literals(&[
        literal_f32(&za)?,
        literal_f32(&zb)?,
        literal_i32(&perm)?,
    ])?;
    let device = scalar(&out[0])?;
    let host =
        0.125 * regularizer::barlow_twins_sum_loss(&za, &zb, 2f32.powi(-10), Q::L2);
    println!("device loss = {device:.6}, host reference = {host:.6}");

    // --- 2b. The same R_sum through the DecorrelationKernel trait --------
    // The kernel plans its FFTs once, accumulates the batch with zero
    // per-sample allocation, and evaluates on read — the API the bench
    // harness and trainer diagnostics use.
    let mut sa = za.clone();
    let mut sb = zb.clone();
    sa.standardize_columns(1e-6);
    sb.standardize_columns(1e-6);
    let mut kernel = FftSumvecKernel::new(d);
    kernel.accumulate(&sa, &sb);
    let r_sum = kernel.r_sum(n as f32, Q::L2);
    let r_sum_free = regularizer::r_sum_fft(&sa, &sb, n as f32, Q::L2);
    println!(
        "host kernel R_sum = {r_sum:.6} over {} samples (free-function check {r_sum_free:.6})",
        kernel.samples()
    );

    // --- 2c. The typed api front door ------------------------------------
    // A LossSpec names one point of the paper's design space; the kernel,
    // artifact ids, and labels above are all derived from it. The
    // HostExecutor wraps the standardize + accumulate + evaluate dance.
    let spec = LossSpec::parse("bt_sum")?;
    let mut exec = spec.host_executor(d)?;
    let facade = exec.evaluate(&za, &zb)?;
    println!(
        "spec '{}' ({}) via HostExecutor: R_sum = {:.6}, loss artifact id '{}'",
        spec,
        spec.display_name(),
        facade.regularizer.unwrap_or(f64::NAN),
        spec.loss_artifact(d, n, false),
    );

    // --- 3. A few pretraining steps --------------------------------------
    // Drivers are built through the api::train front door: one fallible
    // DriverBuilder covers fresh runs, session reuse, DDP, and resume.
    let mut cfg = TrainConfig::preset_tiny();
    cfg.epochs = 1;
    cfg.steps_per_epoch = 10;
    cfg.out_dir = String::new();
    let mut trainer = DriverBuilder::new(cfg).build_trainer()?;
    let report = trainer.run()?;
    println!(
        "tiny pretrain: {} steps, loss {:.4} -> {:.4} ({:.1} steps/s)",
        report.steps, report.initial_loss, report.final_loss, report.steps_per_sec
    );
    println!("quickstart OK");
    Ok(())
}
