//! Serving quickstart: stand up a `decorr serve` instance in-process,
//! drive it with protocol clients, and read the stats it drains with.
//!
//! 1. Start a host-mode server on a private unix socket — no artifacts,
//!    no free TCP port, no external process needed.
//! 2. Score row pairs (the per-row circular cross-correlation quantity)
//!    and cross-check a response against the in-process `RowScorer`:
//!    micro-batched serving is bit-identical to computing locally.
//! 3. Ask for a whole-matrix diagnose (the spec's full `LossExecutor`).
//! 4. Drain gracefully and print the latency/batch tables — the same
//!    tables `decorr serve-bench --json` writes as `BENCH_serving.json`.
//!
//! Run with: `cargo run --release --offline --example serving_quickstart`
//! (no artifacts required — everything here is the host path).

use std::time::Duration;

use anyhow::Result;
use decorr::api::LossSpec;
use decorr::serve::exec::RowScorer;
use decorr::serve::{
    serve, ExecMode, Request, RequestKind, Response, ServeAddr, ServeClient, ServeConfig,
};
use decorr::util::rng::Rng;

fn main() -> Result<()> {
    // --- 1. An in-process server on a private unix socket ----------------
    let sock = std::env::temp_dir().join(format!("decorr-quickstart-{}.sock", std::process::id()));
    let handle = serve(ServeConfig {
        addr: ServeAddr::Unix(sock),
        workers: 2,
        batch_rows: 32,
        deadline: Duration::from_millis(2),
        mode: ExecMode::Host,
        ..ServeConfig::default()
    })?;
    println!("serving on {}", handle.local_addr());

    // --- 2. Score requests ------------------------------------------------
    let (rows, d) = (4usize, 64usize);
    let mut rng = Rng::new(7);
    let mut client = ServeClient::connect(handle.local_addr())?;
    let req = Request {
        id: 1,
        kind: RequestKind::Score,
        spec: "bt_sum".to_string(),
        rows,
        d,
        a: (0..rows * d).map(|_| rng.gaussian()).collect(),
        b: (0..rows * d).map(|_| rng.gaussian()).collect(),
    };
    let Response::Score { scores, .. } = client.call(&req)? else {
        anyhow::bail!("expected a Score response");
    };
    for (r, s) in scores.iter().enumerate() {
        println!("row {r}: score {:.6}, aligned-lag c0 {:.6}", s.score, s.align);
    }
    // The served result is bit-identical to scoring locally: coalescing
    // rows from many requests into one micro-batch cannot perturb them.
    let spec = LossSpec::parse("bt_sum")?;
    let local = RowScorer::new(d, spec.q()).score_rows(rows, &req.a, &req.b);
    assert!(scores
        .iter()
        .zip(&local)
        .all(|(a, b)| a.score.to_bits() == b.score.to_bits()));
    println!("served scores match the local RowScorer bit-for-bit");

    // --- 3. A whole-matrix diagnose ---------------------------------------
    let diag = Request {
        id: 2,
        kind: RequestKind::Diagnose,
        spec: "vic_sum".to_string(),
        rows: 16,
        d,
        a: (0..16 * d).map(|_| rng.gaussian()).collect(),
        b: (0..16 * d).map(|_| rng.gaussian()).collect(),
    };
    if let Response::Diagnose {
        backend,
        total,
        invariance,
        regularizer,
        ..
    } = client.call(&diag)?
    {
        println!(
            "diagnose vic_sum via {backend:?}: total {total:.6}, invariance {:?}, regularizer {:?}",
            invariance, regularizer
        );
    }

    // --- 4. Graceful drain + the serving tables ---------------------------
    client.finish_sending()?;
    drop(client);
    let report = handle.join()?;
    println!(
        "\nserved {} requests over {} connection(s)",
        report.stats.total_requests(),
        report.stats.connections
    );
    report.stats.latency_table().print();
    report.stats.batch_table().print();
    println!("serving quickstart OK");
    Ok(())
}
