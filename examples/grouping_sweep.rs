//! Block-size sweep (paper Fig. 3): time/memory of R_sum^(b) at fixed
//! d = 2048 as the block size b runs from R_off-like (small b) to fully
//! relaxed (b = d). Demonstrates the O((n d²/b) log b) interpolation of
//! Eq. (13).
//!
//! Run with: `cargo run --release --offline --example grouping_sweep
//!            [--blocks 8,32,128,512,2048] [--accuracy]`
//!
//! `--accuracy` additionally pretrains the small preset at b ∈ {128, d}
//! and reports linear-eval accuracy (the Fig. 3 accuracy panel; slower).

use anyhow::Result;
use decorr::api::LossSpec;
use decorr::bench_harness::cmd::pretrain_and_eval;
use decorr::bench_harness::{bench_for, LossWorkload, Table};
use decorr::config::{TrainConfig, Variant};
use decorr::regularizer::kernel::{DecorrelationKernel, GroupedFftKernel, NaiveMatrixKernel};
use decorr::regularizer::Q;
use decorr::runtime::Session;
use decorr::util::cli::Args;
use decorr::util::rng::Rng;
use decorr::util::tensor::Tensor;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let blocks: Vec<usize> = args.list_or("blocks", &[8usize, 32, 128, 512, 2048])?;
    let d = args.get_or("d", 2048usize)?;
    let n = args.get_or("n", 128usize)?;
    let budget = args.get_or("budget", 0.4f64)?;
    let with_accuracy = args.switch("accuracy");
    args.finish()?;

    // Host-side interpolation first (needs no artifacts): the same Eq. 13
    // sweep through the GroupedFftKernel on the pure-rust substrate, with
    // the NaiveMatrixKernel as the b = 1 (≡ R_off) endpoint.
    let (hn, hd) = (64usize, 512usize);
    let mut rng = Rng::new(0x9501);
    let ha = Tensor::from_vec(&[hn, hd], (0..hn * hd).map(|_| rng.gaussian()).collect());
    let hb = Tensor::from_vec(&[hn, hd], (0..hn * hd).map(|_| rng.gaussian()).collect());
    let mut host = Table::new(&["b", "host kernel (ms)", "R_sum^b (q=2)"]);
    let mut naive = NaiveMatrixKernel::new(hd);
    let t_naive = bench_for(0.2, 1, || {
        naive.reset();
        naive.accumulate(&ha, &hb);
        naive.r_off(hn as f32).unwrap()
    });
    let v_naive = naive.r_off(hn as f32).unwrap();
    host.row(vec![
        "1 (= R_off, naive)".into(),
        format!("{:.2}", t_naive.median_ms()),
        format!("{v_naive:.4}"),
    ]);
    // Single-threaded like the naive endpoint, so the b-interpolation
    // column reflects algorithmic cost, not thread count.
    for b in [8usize, 32, 128, hd] {
        let mut kernel = GroupedFftKernel::new(hd, b);
        let stats = bench_for(0.2, 1, || {
            kernel.reset();
            kernel.accumulate(&ha, &hb);
            kernel.r_sum(hn as f32, Q::L2)
        });
        let value = kernel.r_sum(hn as f32, Q::L2);
        host.row(vec![
            if b == hd { format!("{hd} (no grouping)") } else { format!("{b}") },
            format!("{:.2}", stats.median_ms()),
            format!("{value:.4}"),
        ]);
    }
    println!("\nhost DecorrelationKernel sweep (d={hd}, n={hn}, no artifacts needed):");
    host.print();

    let session = Session::open("artifacts")?;
    let mut table = Table::new(&["b", "fwd (ms)", "fwd+bwd (ms)", "loss-node MB"]);
    let mut add = |label: String, spec: LossSpec| -> Result<()> {
        let fwd = LossWorkload::for_spec(&session, &spec, d, n, false)?;
        let f = bench_for(budget, 2, || fwd.run().unwrap());
        let bwd = LossWorkload::for_spec(&session, &spec, d, n, true)?;
        let b = bench_for(budget, 2, || bwd.run().unwrap());
        table.row(vec![
            label,
            format!("{:.2}", f.median_ms()),
            format!("{:.2}", b.median_ms()),
            format!("{:.1}", spec.loss_node_bytes(n, d) as f64 / 1e6),
        ]);
        Ok(())
    };
    add("1 (= R_off)".into(), LossSpec::parse("bt_off")?)?;
    for &b in &blocks {
        if b >= d {
            add(format!("{d} (no grouping)"), LossSpec::parse("bt_sum")?)?;
        } else {
            add(format!("{b}"), LossSpec::parse(&format!("bt_sum@b={b}"))?)?;
        }
    }
    println!("\nFig. 3 analogue (block-size sweep at d={d}, n={n}):");
    table.print();

    if with_accuracy {
        println!("\naccuracy panel (small preset, b = 128 vs no grouping):");
        let mut acc = Table::new(&["b", "top-1 (%)"]);
        let mut eval_session = None;
        for (label, variant) in [("128", Variant::BtSumG128), ("d (no grouping)", Variant::BtSum)]
        {
            let mut cfg = TrainConfig::preset_small();
            cfg.spec = variant.spec();
            let out = pretrain_and_eval(cfg, 1536, 512, 150, eval_session)?;
            acc.row(vec![label.to_string(), format!("{:.2}", out.top1)]);
            eval_session = Some(out.session);
        }
        acc.print();
    }
    Ok(())
}
